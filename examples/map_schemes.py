"""Scheme-mapping example: run BOTH mapping methods (paper §5) on a conv
net and print the per-layer decisions side by side.

  PYTHONPATH=src python examples/map_schemes.py
"""
import jax

from benchmarks.common import train_convnet
from benchmarks.bench_mapping import _convnet_eval_factory
from repro.core import mapper_rule as MR
from repro.core import mapper_search as MS
from repro.core.reweighted import match
from repro.models import convnet as C


def main():
    layers = MR.conv_layers([
        (n, 16 // max(s, 1), cin, o, kh, kw, dw) for
        (n, o, kh, kw, s, dw), cin in zip(
            C.MOBILE_TINY, [3, 32, 32, 64, 64, 128])])

    print("== rule-based (training-free, Fig 8) ==")
    spec_r, report = MR.map_rules(layers, dataset_hard=False,
                                  compression=5.0)
    for r in report:
        print(f"  {r['path']:6s} [{r['kind']:8s}] -> {r['scheme']:14s} "
              f"block={r['block']}")

    print("== search-based (REINFORCE, §5.1; small budget) ==")
    dense = train_convnet(arch=C.MOBILE_TINY, steps=60, seed=3)
    evaluate = _convnet_eval_factory(dense, steps=20)
    best, hist = MS.search(layers, evaluate, iters=5, samples=3,
                           latency_weight=2e2, verbose=True,
                           key=jax.random.PRNGKey(0))
    for ld in layers:
        c = match(best, ld.path)
        print(f"  {ld.path:6s} [{ld.kind:8s}] -> {c.scheme:14s} "
              f"block={c.block}")
    print(f"reward trend: {hist[0]:.4f} -> {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
