"""Quickstart: the paper's pipeline in ~70 lines.

  1. build a (smoke-sized) LM,
  2. map best-suited pruning schemes per layer (rule-based, training-free),
  3. train with reweighted dynamic regularization,
  4. threshold -> masks (automatic per-layer/per-block rates),
  5. finetune, report compression,
  6. COMPILE the pruned model (pack block-pruned layers to the BCS layout,
     ``serve.compile.compile_model``) and serve it on the sparse kernel
     through the fused decode loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs
from repro.core import pruner, reweighted as RW
from repro.core.mapper_rule import lm_layers, map_rules
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T
from repro.serve.compile import compile_model, compiled_summary
from repro.serve.engine import generate
from repro.train.trainer import make_train_step

ARCH = "yi-9b"


def main():
    cfg = configs.get(ARCH, smoke=True)
    print(f"arch={cfg.name} (smoke: {cfg.n_layers}L d={cfg.d_model})")

    # 1-2: model + training-free scheme mapping (paper §5.2)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    spec, report = map_rules(lm_layers(cfg, tokens=512),
                             dataset_hard=False, compression=4.0)
    spec = [(p, RW.SchemeChoice(c.scheme, (8, 16))
             if c.scheme != "none" else c) for p, c in spec]   # smoke dims
    for r in report[:4]:
        print(f"  map {r['path']:<22s} -> {r['scheme']} {r['block']}")

    # 3-5: reweighted train -> auto-threshold -> finetune (paper §4.2)
    rw = RW.ReweightedConfig(spec=tuple(spec), lam=2e-3)
    opt_init, step = make_train_step(cfg, lr=3e-3, reweighted=rw)
    step = jax.jit(step)
    bf = lambda s: synthetic_batch(0, s, 8, 32, cfg.vocab)
    res = pruner.reweighted_prune(params, opt_init(params), spec, step, bf,
                                  steps=60, reweight_every=15,
                                  target_rate=0.5, finetune_steps=30,
                                  verbose=True)
    overall = res.report["__overall__"]
    print(f"compression: {overall['compression']:.2f}x "
          f"(density {overall['density']:.3f})")

    # 6: compile for sparse execution — pack block-pruned layers into the
    # BCS layout so serving dispatches through the Pallas kernel
    exec_params, creport = compile_model(res.params, res.masks, spec)
    print(compiled_summary(creport))

    # run the compiled model (fused prefill + scan decode)
    out = generate(exec_params, cfg, bf(0)["tokens"][:2], 8)
    print("pruned model generates:", out[0].tolist())


if __name__ == "__main__":
    main()
