"""Serving example: the compiler/runtime half of the paper (§4.3) end to
end — (a) one projection packed to BCS and executed on the Pallas
block-sparse kernel, (b) a WHOLE model block-pruned, compiled with
``compile_model``, and served through the fused scan decode loop.

  PYTHONPATH=src python examples/serve_sparse.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import bcs as BCS
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.kernels.ref import masked_matmul_ref
from repro.models import transformer as T
from repro.data.pipeline import synthetic_batch
from repro.serve.compile import (CompileSpec, compile_model,
                                 compiled_summary)
from repro.serve.engine import generate
from repro.train.trainer import apply_masks


def kernel_demo():
    """One projection: pack -> sparse kernel -> compare vs masked oracle."""
    K, N = 512, 1024
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N), jnp.float32)
    # block pruning at ~4x with whole blocks dying (structured collapse)
    keep = jax.random.uniform(jax.random.PRNGKey(1), (K // 128, N // 128))
    mask = jnp.repeat(jnp.repeat(keep > 0.75, 128, 0), 128, 1)
    mask = mask.astype(jnp.float32)
    packed = ops.pack(w, mask, (128, 128))
    b = BCS.from_dense(np.asarray(w), np.asarray(mask), (128, 128))
    print(f"density={packed.density:.2f}  "
          f"flops_skipped(effective)={ops.flops_saved(packed)*100:.0f}%  "
          f"pad_overhead={ops.padding_overhead(packed):.2f}x  "
          f"BCS idx bytes={b.index_bytes()} (CSR {b.csr_index_bytes()})")
    x = jax.random.normal(jax.random.PRNGKey(2), (256, K), jnp.float32)
    y = ops.sparse_linear(x, packed=packed, bm=128)
    err = float(jnp.max(jnp.abs(y - masked_matmul_ref(x, w, mask))))
    print(f"kernel max err vs oracle: {err:.2e}")


def whole_model_demo():
    """Block-prune a smoke model, compile it, and serve on the kernel."""
    mapping = [(r"(attn/w[qkvo]|ffn/(gate|up|down))/w",
                RW.SchemeChoice("block", (16, 16)))]
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    # whole (16,16) blocks die — the structured collapse the kernel skips
    masks = RW.random_block_masks(params, mapping, (16, 16), keep_prob=0.4)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, mapping,
                                        spec=CompileSpec(keep_dense=False))
    print(compiled_summary(report))
    batch = synthetic_batch(0, 0, 4, 32, cfg.vocab)
    t0 = time.time()
    out = jax.block_until_ready(
        generate(exec_params, cfg, batch["tokens"], 16))
    print(f"compiled sparse model: {out.shape[0]}x{out.shape[1]} tokens in "
          f"{time.time()-t0:.2f}s (incl. compile)")


def batched_serving_demo():
    for arch in ("mixtral-8x7b", "mamba2-1.3b"):
        cfg = configs.get(arch, smoke=True)
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        batch = synthetic_batch(0, 0, 4, 32, cfg.vocab)
        t0 = time.time()
        out = generate(params, cfg, batch["tokens"], 16)
        print(f"{arch}: {out.shape[0]}x{out.shape[1]} tokens in "
              f"{time.time()-t0:.2f}s (incl. compile)")


def main():
    kernel_demo()
    whole_model_demo()
    batched_serving_demo()


if __name__ == "__main__":
    main()
