"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the synthetic bigram corpus, with checkpointing + pruning schedule —
the deliverable-(b) 'train a ~100M model' example.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--tiny]

(--tiny drops to the smoke config so the example finishes in ~1 min on the
CPU container; without it the config is a true ~100M model.)
"""
import argparse


from repro.configs.base import ArchConfig
from repro.launch import train as train_driver

# ~100M dense transformer (GQA, SwiGLU) — real example scale
CFG_100M = ArchConfig(
    name="repro-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32000, head_dim=64, remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "yi-9b", "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--prune",
                "--target-rate", "0.5"]
        train_driver.main(argv)
        return

    # register the 100M config under a module-free path: monkeypatch get
    import repro.configs as configs
    real_get = configs.get

    def patched(name, smoke=False):
        if name == "repro-100m":
            return CFG_100M
        return real_get(name, smoke)
    configs.get = patched
    train_driver.configs.get = patched
    train_driver.main(["--arch", "repro-100m", "--steps", str(args.steps),
                       "--batch", "8", "--seq", "256", "--prune",
                       "--target-rate", "0.5", "--ckpt-every", "100"])


if __name__ == "__main__":
    main()
