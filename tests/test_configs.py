"""Config fidelity: every assigned architecture's published numbers are
exactly what the framework instantiates (deliverable f)."""
import pytest

from repro import configs
from repro.configs.base import SHAPES

# (arch, n_layers, d_model, n_heads, n_kv, d_ff, vocab, extras)
ASSIGNED = [
    ("seamless-m4t-large-v2", 24, 1024, 16, 16, 8192, 256206,
     {"family": "encdec", "n_enc_layers": 24}),
    ("yi-9b", 48, 4096, 32, 4, 11008, 64000, {"family": "dense"}),
    ("granite-8b", 36, 4096, 32, 8, 14336, 49152, {"family": "dense"}),
    ("minitron-8b", 32, 4096, 32, 8, 16384, 256000, {"family": "dense"}),
    ("phi3-medium-14b", 40, 5120, 40, 10, 17920, 100352,
     {"family": "dense"}),
    ("mamba2-1.3b", 48, 2048, 0, 0, 0, 50280,
     {"family": "ssm", "ssm_state": 128, "supports_long": True}),
    ("mixtral-8x7b", 32, 4096, 32, 8, 14336, 32000,
     {"family": "moe", "n_experts": 8, "top_k": 2,
      "sliding_window": 4096}),
    ("kimi-k2-1t-a32b", 61, 7168, 64, 8, 2048, 163840,
     {"family": "moe", "n_experts": 384, "top_k": 8}),
    ("hymba-1.5b", 32, 1600, 25, 5, 5504, 32001,
     {"family": "hybrid", "ssm_state": 16, "supports_long": True}),
    ("llama-3.2-vision-90b", 100, 8192, 64, 8, 28672, 128256,
     {"family": "vlm", "cross_attn_interval": 5}),
]


@pytest.mark.parametrize("row", ASSIGNED, ids=[r[0] for r in ASSIGNED])
def test_assigned_config_numbers(row):
    arch, L, D, H, KV, F, V, extras = row
    cfg = configs.get(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == D
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == F
    assert cfg.vocab == V
    for k, v in extras.items():
        assert getattr(cfg, k) == v, (arch, k)


def test_assigned_shapes():
    assert SHAPES["train_4k"] == dict(seq=4096, batch=256, kind="train")
    assert SHAPES["prefill_32k"] == dict(seq=32768, batch=32, kind="prefill")
    assert SHAPES["decode_32k"] == dict(seq=32768, batch=128, kind="decode")
    assert SHAPES["long_500k"] == dict(seq=524288, batch=1, kind="decode")


def test_long_context_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §3)."""
    runs = {a for a in configs.ALIASES
            if configs.cell_is_supported(configs.get(a), "long_500k")[0]}
    assert runs == {"mamba2-1.3b", "hymba-1.5b", "mixtral-8x7b"}


@pytest.mark.parametrize("arch", list(configs.ALIASES))
def test_smoke_config_is_same_family(arch):
    full, smoke = configs.get(arch), configs.get(arch, smoke=True)
    assert smoke.family == full.family
    assert smoke.d_model <= 64 or smoke.d_model < full.d_model // 4
    if full.n_experts:
        assert smoke.n_experts > 1 and smoke.top_k >= 1


@pytest.mark.parametrize("arch", list(configs.ALIASES))
@pytest.mark.parametrize("shape_id", list(SHAPES))
def test_input_specs_shapes(arch, shape_id):
    cfg = configs.get(arch)
    ok, _ = configs.cell_is_supported(cfg, shape_id)
    if not ok:
        return
    specs = configs.input_specs(cfg, shape_id)
    sh = SHAPES[shape_id]
    if sh["kind"] in ("train", "prefill"):
        assert specs["tokens"].shape == (sh["batch"], sh["seq"])
    else:
        assert specs["token"].shape == (sh["batch"], 1)
        assert "cache" in specs
    if cfg.family in ("encdec", "vlm") and sh["kind"] != "decode":
        assert specs["frontend"].shape[2] == cfg.d_model
