"""Rule-based + search-based pruning-scheme mapping tests (paper §5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import mapper_rule as MR
from repro.core import mapper_search as MS
from repro.core.latency_model import matmul_latency


class TestRuleBased:
    def test_depthwise_never_pruned(self):
        """§5.2.4: no scheme mapped to depthwise convs (ssm conv1d)."""
        layers = MR.lm_layers(configs.get("mamba2-1.3b"), tokens=4096)
        spec, report = MR.map_rules(layers)
        for r in report:
            if r["kind"] == "dw":
                assert r["scheme"] == "none"

    def test_router_and_embed_frozen(self):
        layers = MR.lm_layers(configs.get("mixtral-8x7b"), tokens=4096)
        spec, report = MR.map_rules(layers)
        by_path = {r["path"]: r for r in report}
        assert by_path[r"moe/router"]["scheme"] == "none"
        assert by_path[r"embed/table"]["scheme"] == "none"

    def test_remark1_dataset_rule(self):
        """Remark 1: 3x3 conv -> pattern on hard datasets, block on easy."""
        convs = MR.conv_layers([("c1", 28, 64, 64, 3, 3, False)])
        spec_h, rep_h = MR.map_rules(convs, dataset_hard=True)
        spec_e, rep_e = MR.map_rules(convs, dataset_hard=False)
        assert rep_h[0]["scheme"] == "pattern"
        assert rep_e[0]["scheme"] == "block_punched"

    def test_block_size_beta_rule(self):
        """§5.2.2: chosen block is the smallest whose latency is within
        (1+beta) of structured — larger beta can only shrink the block."""
        b_tight, _, _ = MR.select_block_size(4096, 4096, 4096, 8.0,
                                             beta=0.05)
        b_loose, _, _ = MR.select_block_size(4096, 4096, 4096, 8.0,
                                             beta=3.0)
        assert b_loose[0] * b_loose[1] <= b_tight[0] * b_tight[1]

    def test_all_archs_map(self):
        for arch in configs.ALIASES:
            layers = MR.lm_layers(configs.get(arch), tokens=8192)
            spec, report = MR.map_rules(layers)
            assert len(spec) == len(report) > 0
            assert MR.total_latency(report) > 0

    def test_spec_paths_match_real_params(self):
        """Every non-none rule must match at least one real param path."""
        from repro.models import transformer as T
        from repro.models.module import path_str
        import re
        cfg = configs.get("mixtral-8x7b", smoke=True)
        params = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0),
                                                  cfg))
        paths = [path_str(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(params)[0]]
        layers = MR.lm_layers(cfg, tokens=512)
        spec, _ = MR.map_rules(layers)
        for pat, choice in spec:
            if choice.scheme != "none":
                assert any(re.search(pat, p) for p in paths), pat


class TestSearchBased:
    def test_applicability_masks(self):
        assert not MS.applicable("fc")[MS.SCHEME_MENU.index("pattern")]
        assert MS.applicable("conv3x3")[MS.SCHEME_MENU.index("pattern")]
        m = MS.applicable("dw")
        assert m[0] and not m[1:].any()

    def test_sample_respects_masks(self):
        layers = MR.conv_layers([("dw1", 14, 32, 32, 3, 3, True),
                                 ("c2", 14, 32, 64, 3, 3, False)])
        feats = jnp.asarray(MS.layer_features(layers))
        app = jnp.asarray(np.stack([MS.applicable(l.kind) for l in layers]))
        p = MS.policy_init(jax.random.PRNGKey(0), feats.shape[1], 16)
        for seed in range(5):
            a_s, a_b, a_p, logp = MS.sample_mapping(p, feats, app,
                                                    jax.random.PRNGKey(seed))
            assert MS.SCHEME_MENU[int(a_s[0])] == "none"   # dw forced
            assert a_p.shape == a_s.shape
            assert np.isfinite(float(logp))

    def test_search_improves_reward(self):
        """REINFORCE learns to prefer the high-reward mapping on a toy
        problem where one scheme is strictly better."""
        layers = MR.conv_layers([("c1", 14, 64, 64, 3, 3, False)] * 3)

        def evaluate(spec):
            # contrived: reward block over everything else
            return float(np.mean([c.scheme == "block" for _, c in spec]))

        best, hist = MS.search(layers, evaluate, iters=60, samples=8,
                               lr=0.15, latency_weight=0.0,
                               key=jax.random.PRNGKey(0))
        assert np.mean(hist[-5:]) > np.mean(hist[:5])
        assert evaluate(best) >= 2 / 3

    def test_actions_to_spec_snaps_blocks(self):
        layers = [MR.LayerDesc("x/w", "fc", 128, 100, 60)]
        spec = MS.actions_to_spec(layers, np.array([4]), np.array([5]))
        _, choice = spec[0]
        assert 100 % choice.block[0] == 0 and 60 % choice.block[1] == 0


def test_latency_model_shapes():
    """Fig 9 behavior: latency falls as block grows, then saturates; Fig 5:
    unstructured slowest, structured fastest."""
    M, K, N = 4096, 512, 512
    lats = [matmul_latency(M, K, N, scheme="block", block=b, compression=8)
            for b in [(4, 4), (16, 32), (64, 128), (128, 128)]]
    assert lats[0] > lats[-1]                       # small blocks slower
    t_un = matmul_latency(M, K, N, scheme="unstructured", compression=8)
    t_st = matmul_latency(M, K, N, scheme="structured_row", compression=8)
    assert t_un > lats[-1] > t_st * 0.5
    # higher compression never slower (same scheme/block)
    l4 = matmul_latency(M, K, N, scheme="block", block=(128, 128),
                        compression=4)
    l16 = matmul_latency(M, K, N, scheme="block", block=(128, 128),
                         compression=16)
    assert l16 <= l4
