"""CONV layers as PackedLayout producers/consumers: im2col lowering
round-trips, packed-vs-masked-dense parity on both tiny conv archs
(including the 5x5 and stride-2 layers), reorder bit-identity through
``sparse_conv2d``, implicit-GEMM parity (the patch tensor never
materialized — asserted by poisoning ``ops.im2col``), im2col edge cases
(VALID / non-square / kernel-larger-than-feature-map) on both paths, and
the depthwise / indivisible skip regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.models import convnet as C
from repro.serve.compile import (CompileSpec, compile_model,
                                 compiled_summary)
from repro.train.trainer import apply_masks

CONV_SPEC = [(r"(^|/)(c|pw|dw)\d+/w", RW.SchemeChoice("block_punched",
                                                      (8, 8)))]


def conv_case(P, Q, kh, kw, rate=0.5, block=(8, 8), seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, block, rate=rate)
    return w * mask, mask


def dense_conv(wm, x, stride, padding="SAME"):
    kernel = wm.transpose(2, 3, 1, 0)            # (kh,kw,Q,P)
    return jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def packed_conv_layout(wm, mask, block=(8, 8), **kw):
    return ops.pack(BCS.conv_lower(wm), BCS.conv_lower(mask), block, **kw)


# -- lowering: im2col GEMM == lax.conv, punched masks -> dead blocks ---------

@pytest.mark.parametrize("P,Q,kh,kw,stride", [
    (32, 16, 3, 3, 1),
    (64, 32, 5, 5, 2),      # non-3x3 kernel AND stride 2
    (32, 16, 1, 1, 1),
])
def test_sparse_conv2d_matches_dense_conv(P, Q, kh, kw, stride):
    wm, mask = conv_case(P, Q, kh, kw)
    gemm_block, why = BCS.conv_gemm_block((8, 8), wm.shape)
    assert gemm_block == (8, 8) and why is None
    packed = ops.pack(BCS.conv_lower(wm), BCS.conv_lower(mask), gemm_block,
                      reorder=True, n_bins=4)
    # punched groups became whole dead BCS blocks: real executed-L savings
    assert packed.flops_saved > 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, Q), jnp.float32)
    y = ops.sparse_conv2d(x, packed, kh=kh, kw=kw, stride=stride)
    y_ref = dense_conv(wm, x, stride)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_conv_lower_row_order_is_tap_major():
    """Row r of the lowered weight = channel q at tap (i, j) with
    r = (i*Kw + j)*Q + q — the contract im2col relies on."""
    P, Q, Kh, Kw = 4, 3, 2, 2
    w = np.arange(P * Q * Kh * Kw, dtype=np.float32).reshape(P, Q, Kh, Kw)
    wl = BCS.conv_lower(w)
    assert wl.shape == (Kh * Kw * Q, P)
    for i in range(Kh):
        for j in range(Kw):
            for q in range(Q):
                np.testing.assert_array_equal(wl[(i * Kw + j) * Q + q],
                                              w[:, q, i, j])


@pytest.mark.parametrize("n_bins", [1, 2, 4])
def test_sparse_conv2d_reorder_bit_identity(n_bins):
    """Row-reordered conv layouts produce bit-identical outputs — the
    epilogue gather relabels output channels, accumulation is untouched."""
    wm, mask = conv_case(64, 32, 3, 3, rate=0.7, seed=3)
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    plain = ops.pack(wl, ml, (8, 8))
    reord = ops.pack(wl, ml, (8, 8), reorder=True, n_bins=n_bins)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 9, 9, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    y0 = ops.sparse_conv2d(x, plain, kh=3, kw=3, stride=2, bias=b,
                           act="relu")
    y1 = ops.sparse_conv2d(x, reord, kh=3, kw=3, stride=2, bias=b,
                           act="relu")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert reord.L_effective <= plain.L_max


# -- implicit-GEMM path: im2col folded into the kernel -----------------------

@pytest.mark.parametrize("P,Q,kh,kw,stride", [
    (32, 16, 3, 3, 1),
    (64, 32, 5, 5, 2),      # non-3x3 kernel AND stride 2
    (32, 16, 3, 3, 2),
])
def test_implicit_conv_bit_identical_to_materialized(P, Q, kh, kw, stride):
    """The implicit kernel gathers exactly the im2col rows, so its output
    is BIT-identical to the materialized path (and fp32-close to the
    masked ``lax.conv`` oracle)."""
    wm, mask = conv_case(P, Q, kh, kw)
    packed = packed_conv_layout(wm, mask, reorder=True, n_bins=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, Q), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (P,), jnp.float32)
    y_imp = ops.sparse_conv2d(x, packed, kh=kh, kw=kw, stride=stride,
                              bias=b, act="relu", implicit=True)
    y_mat = ops.sparse_conv2d(x, packed, kh=kh, kw=kw, stride=stride,
                              bias=b, act="relu", implicit=False)
    np.testing.assert_array_equal(np.asarray(y_imp), np.asarray(y_mat))
    y_ref = jax.nn.relu(dense_conv(wm, x, stride) + b)
    np.testing.assert_allclose(np.asarray(y_imp), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("implicit", [False, True])
@pytest.mark.parametrize("H,W,kh,kw,stride,padding", [
    (10, 10, 3, 3, 1, "VALID"),      # VALID padding
    (9, 13, 3, 3, 2, "SAME"),        # non-square input, stride 2
    (11, 7, 5, 5, 1, "VALID"),       # VALID + non-square
    (4, 4, 5, 5, 1, "SAME"),         # kernel larger than the feature map
])
def test_im2col_edge_cases_both_paths(H, W, kh, kw, stride, padding,
                                      implicit):
    """im2col edge cases hold on BOTH x-operand strategies, against the
    ``lax.conv_general_dilated`` oracle."""
    P, Q = 16, 8
    wm, mask = conv_case(P, Q, kh, kw)
    packed = packed_conv_layout(wm, mask, reorder=True, n_bins=2)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, H, W, Q), jnp.float32)
    y = ops.sparse_conv2d(x, packed, kh=kh, kw=kw, stride=stride,
                          padding=padding, implicit=implicit)
    y_ref = dense_conv(wm, x, stride, padding)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_valid_padding_kernel_too_large_raises():
    """VALID padding with a kernel that does not fit must fail loudly on
    both paths, not emit an empty output."""
    wm, mask = conv_case(16, 8, 5, 5)
    packed = packed_conv_layout(wm, mask)
    x = jnp.zeros((1, 4, 4, 8), jnp.float32)
    for implicit in (False, True):
        with pytest.raises(ValueError, match="does not fit"):
            ops.sparse_conv2d(x, packed, kh=5, kw=5, padding="VALID",
                              implicit=implicit)


def test_implicit_never_materializes_patches(monkeypatch):
    """The acceptance property of the implicit mode: the B*Ho*Wo*Kh*Kw*C
    patch tensor is never built — poisoning ``ops.im2col`` must not
    affect the implicit path, while the materialized path dies on it."""
    wm, mask = conv_case(32, 16, 3, 3)
    packed = packed_conv_layout(wm, mask)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 8, 16), jnp.float32)
    y_before = ops.sparse_conv2d(x, packed, kh=3, kw=3, implicit=False)

    def boom(*a, **kw):
        raise AssertionError("patch tensor materialized")

    monkeypatch.setattr(ops, "im2col", boom)
    y_imp = ops.sparse_conv2d(x, packed, kh=3, kw=3, implicit=True)
    np.testing.assert_array_equal(np.asarray(y_imp), np.asarray(y_before))
    with pytest.raises(AssertionError, match="materialized"):
        ops.sparse_conv2d(x, packed, kh=3, kw=3, implicit=False)


def test_implicit_auto_selection_by_patch_size():
    """implicit=None picks by patch-tensor size: tiny patches and 1x1
    convs stay materialized; a patch above the byte floor (or a block
    straddling taps) flips the choice."""
    x_small = jnp.zeros((1, 8, 8, 16), jnp.float32)
    x_big = jnp.zeros((8, 64, 64, 64), jnp.float32)     # ~75 MiB of patches
    assert not ops._pick_implicit(None, x_small, 3, 3, 1, "SAME", bk=8)
    assert ops._pick_implicit(None, x_big, 3, 3, 1, "SAME", bk=8)
    # 1x1: the "patch" IS the input — nothing to avoid
    assert not ops._pick_implicit(None, x_big, 1, 1, 1, "SAME", bk=8)
    # a padded image past the VMEM ceiling never auto-selects implicit
    # (the kernel pins the whole image in VMEM); explicit True still can
    x_huge = jnp.zeros((1, 600, 600, 128), jnp.float32)   # ~185 MiB image
    assert not ops._pick_implicit(None, x_huge, 3, 3, 1, "SAME", bk=8)
    assert ops._pick_implicit(True, x_huge, 3, 3, 1, "SAME", bk=8)
    # a K-block straddling taps cannot run implicit: auto falls back ...
    assert not ops._pick_implicit(None, x_big, 3, 3, 1, "SAME", bk=48)
    # ... and forcing it is a loud error, not silent densification
    with pytest.raises(AssertionError, match="straddle"):
        ops._pick_implicit(True, x_big, 3, 3, 1, "SAME", bk=48)


def test_conv_tap_table_matches_lowering_order():
    """conv_tap_table(kb) = (dy, dx, c0) of the first row of K-block kb
    under the ``conv_lower`` (tap-major, channel-minor) row order."""
    kh, kw, c, bk = 2, 3, 8, 4
    taps = BCS.conv_tap_table(kh, kw, c, bk)
    assert len(taps) == kh * kw * c // bk
    for kb, (dy, dx, c0) in enumerate(taps):
        r0 = kb * bk
        assert r0 == (dy * kw + dx) * c + c0
        assert c0 + bk <= c                      # never straddles a tap
    with pytest.raises(AssertionError, match="straddle"):
        BCS.conv_tap_table(3, 3, 8, 6)           # 6 does not divide 8


def test_compile_attaches_conv_taps():
    """compile_model's conv producer carries the static tap-offset aux so
    serving auto-selects implicit without re-deriving geometry, and the
    report carries the per-position patch bytes the implicit path avoids."""
    _, exec_params, report = _compiled_convnet(C.VGG_TINY)
    for r in report:
        if r["packed"]:
            name = r["path"].split("/")[0]
            layout = exec_params[name]["packed"]
            assert layout.conv_taps is not None
            assert len(layout.conv_taps) == layout.Kb
            assert r["patch_b_per_pos"] > 0
    assert "implicit_avoids=" in compiled_summary(report)


# -- compile_model: whole-convnet packed forward == masked-dense oracle ------

def _compiled_convnet(arch, rate=0.5, seed=0):
    params = C.convnet_init(jax.random.PRNGKey(seed), arch,
                            dtype=jnp.float32)
    masks = RW.punched_conv_masks(params, CONV_SPEC, (8, 8), rate=rate)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, CONV_SPEC)
    return pm, exec_params, report


@pytest.mark.parametrize("arch,expect_packed", [
    (C.VGG_TINY, {"c2", "c3", "c4", "c5", "c6"}),      # stride-2 + 1x1
    (C.MOBILE_TINY, {"pw2", "pw3", "c4"}),             # 5x5 + depthwise mix
])
def test_convnet_packed_forward_parity(arch, expect_packed):
    pm, exec_params, report = _compiled_convnet(arch)
    packed = {r["path"].split("/")[0] for r in report if r["packed"]}
    assert packed == expect_packed, compiled_summary(report)
    assert all(r["kind"] == "conv" for r in report if r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(2), 4)
    y_ref = C.convnet_apply(pm, x, arch)
    y = C.convnet_apply(exec_params, x, arch)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_convnet_packed_drop_dense():
    """keep_dense=False: packed conv layers lose "w" and the net still runs
    through the kernel path (depthwise/stem keep their dense weights)."""
    params = C.convnet_init(jax.random.PRNGKey(0), C.MOBILE_TINY,
                            dtype=jnp.float32)
    masks = RW.punched_conv_masks(params, CONV_SPEC, (8, 8))
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(
        pm, masks, CONV_SPEC, spec=CompileSpec(keep_dense=False))
    for r in report:
        name = r["path"].split("/")[0]
        assert ("w" in exec_params[name]) == (not r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(1), 2)
    y_ref = C.convnet_apply(pm, x, C.MOBILE_TINY)
    y = C.convnet_apply(exec_params, x, C.MOBILE_TINY)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_depthwise_skips_with_logged_reason_not_crash():
    """Regression: depthwise layers must SKIP packing with a logged reason
    (§5.2.4) — never crash, never pack — even when the spec maps them."""
    _, exec_params, report = _compiled_convnet(C.MOBILE_TINY)
    by_name = {r["path"].split("/")[0]: r for r in report}
    for dw_name in ("dw2", "dw3"):
        assert not by_name[dw_name]["packed"]
        assert "depthwise" in by_name[dw_name]["reason"]
        assert "packed" not in exec_params[dw_name]


def test_conv_gemm_block_indivisible_skips():
    """A kernel block that cannot tile (P, Q) skips with the reason in the
    report — e.g. the 3-channel stem conv under an (8, 8) kernel block."""
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 3, 3, 3), jnp.float32)
    gb, why = BCS.conv_gemm_block((8, 8), w.shape)
    assert gb is None and "does not divide" in why
    params = {"c1": {"w": w, "b": jnp.zeros((32,), jnp.float32)}}
    exec_params, report = compile_model(
        params, None, [(r"c1/w", RW.SchemeChoice("block_punched", (8, 8)))])
    assert not report[0]["packed"]
    assert "does not divide" in report[0]["reason"]


def test_block_punched_on_non_conv_weight_skips():
    """block_punched mapped onto a 2-D FC weight must skip, not lower."""
    params = {"fc": {"w": jnp.ones((64, 64), jnp.float32)}}
    exec_params, report = compile_model(
        params, None, [(r"fc/w", RW.SchemeChoice("block_punched", (8, 8)))])
    assert not report[0]["packed"]
    assert "conv weight" in report[0]["reason"]
