"""CONV layers as PackedLayout producers/consumers: im2col lowering
round-trips, packed-vs-masked-dense parity on both tiny conv archs
(including the 5x5 and stride-2 layers), reorder bit-identity through
``sparse_conv2d``, and the depthwise / indivisible skip regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.models import convnet as C
from repro.serve.compile import compile_model, compiled_summary
from repro.train.trainer import apply_masks

CONV_SPEC = [(r"(^|/)(c|pw|dw)\d+/w", RW.SchemeChoice("block_punched",
                                                      (8, 8)))]


def conv_case(P, Q, kh, kw, rate=0.5, block=(8, 8), seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, block, rate=rate)
    return w * mask, mask


def dense_conv(wm, x, stride):
    kernel = wm.transpose(2, 3, 1, 0)            # (kh,kw,Q,P)
    return jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# -- lowering: im2col GEMM == lax.conv, punched masks -> dead blocks ---------

@pytest.mark.parametrize("P,Q,kh,kw,stride", [
    (32, 16, 3, 3, 1),
    (64, 32, 5, 5, 2),      # non-3x3 kernel AND stride 2
    (32, 16, 1, 1, 1),
])
def test_sparse_conv2d_matches_dense_conv(P, Q, kh, kw, stride):
    wm, mask = conv_case(P, Q, kh, kw)
    gemm_block, why = BCS.conv_gemm_block((8, 8), wm.shape)
    assert gemm_block == (8, 8) and why is None
    packed = ops.pack(BCS.conv_lower(wm), BCS.conv_lower(mask), gemm_block,
                      reorder=True, n_bins=4)
    # punched groups became whole dead BCS blocks: real executed-L savings
    assert packed.flops_saved > 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, Q), jnp.float32)
    y = ops.sparse_conv2d(x, packed, kh=kh, kw=kw, stride=stride)
    y_ref = dense_conv(wm, x, stride)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_conv_lower_row_order_is_tap_major():
    """Row r of the lowered weight = channel q at tap (i, j) with
    r = (i*Kw + j)*Q + q — the contract im2col relies on."""
    P, Q, Kh, Kw = 4, 3, 2, 2
    w = np.arange(P * Q * Kh * Kw, dtype=np.float32).reshape(P, Q, Kh, Kw)
    wl = BCS.conv_lower(w)
    assert wl.shape == (Kh * Kw * Q, P)
    for i in range(Kh):
        for j in range(Kw):
            for q in range(Q):
                np.testing.assert_array_equal(wl[(i * Kw + j) * Q + q],
                                              w[:, q, i, j])


@pytest.mark.parametrize("n_bins", [1, 2, 4])
def test_sparse_conv2d_reorder_bit_identity(n_bins):
    """Row-reordered conv layouts produce bit-identical outputs — the
    epilogue gather relabels output channels, accumulation is untouched."""
    wm, mask = conv_case(64, 32, 3, 3, rate=0.7, seed=3)
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    plain = ops.pack(wl, ml, (8, 8))
    reord = ops.pack(wl, ml, (8, 8), reorder=True, n_bins=n_bins)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 9, 9, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    y0 = ops.sparse_conv2d(x, plain, kh=3, kw=3, stride=2, bias=b,
                           act="relu")
    y1 = ops.sparse_conv2d(x, reord, kh=3, kw=3, stride=2, bias=b,
                           act="relu")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert reord.L_effective <= plain.L_max


# -- compile_model: whole-convnet packed forward == masked-dense oracle ------

def _compiled_convnet(arch, rate=0.5, seed=0):
    params = C.convnet_init(jax.random.PRNGKey(seed), arch,
                            dtype=jnp.float32)
    masks = RW.punched_conv_masks(params, CONV_SPEC, (8, 8), rate=rate)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, CONV_SPEC)
    return pm, exec_params, report


@pytest.mark.parametrize("arch,expect_packed", [
    (C.VGG_TINY, {"c2", "c3", "c4", "c5", "c6"}),      # stride-2 + 1x1
    (C.MOBILE_TINY, {"pw2", "pw3", "c4"}),             # 5x5 + depthwise mix
])
def test_convnet_packed_forward_parity(arch, expect_packed):
    pm, exec_params, report = _compiled_convnet(arch)
    packed = {r["path"].split("/")[0] for r in report if r["packed"]}
    assert packed == expect_packed, compiled_summary(report)
    assert all(r["kind"] == "conv" for r in report if r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(2), 4)
    y_ref = C.convnet_apply(pm, x, arch)
    y = C.convnet_apply(exec_params, x, arch)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_convnet_packed_drop_dense():
    """keep_dense=False: packed conv layers lose "w" and the net still runs
    through the kernel path (depthwise/stem keep their dense weights)."""
    params = C.convnet_init(jax.random.PRNGKey(0), C.MOBILE_TINY,
                            dtype=jnp.float32)
    masks = RW.punched_conv_masks(params, CONV_SPEC, (8, 8))
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, CONV_SPEC,
                                        keep_dense=False)
    for r in report:
        name = r["path"].split("/")[0]
        assert ("w" in exec_params[name]) == (not r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(1), 2)
    y_ref = C.convnet_apply(pm, x, C.MOBILE_TINY)
    y = C.convnet_apply(exec_params, x, C.MOBILE_TINY)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_depthwise_skips_with_logged_reason_not_crash():
    """Regression: depthwise layers must SKIP packing with a logged reason
    (§5.2.4) — never crash, never pack — even when the spec maps them."""
    _, exec_params, report = _compiled_convnet(C.MOBILE_TINY)
    by_name = {r["path"].split("/")[0]: r for r in report}
    for dw_name in ("dw2", "dw3"):
        assert not by_name[dw_name]["packed"]
        assert "depthwise" in by_name[dw_name]["reason"]
        assert "packed" not in exec_params[dw_name]


def test_conv_gemm_block_indivisible_skips():
    """A kernel block that cannot tile (P, Q) skips with the reason in the
    report — e.g. the 3-channel stem conv under an (8, 8) kernel block."""
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 3, 3, 3), jnp.float32)
    gb, why = BCS.conv_gemm_block((8, 8), w.shape)
    assert gb is None and "does not divide" in why
    params = {"c1": {"w": w, "b": jnp.zeros((32,), jnp.float32)}}
    exec_params, report = compile_model(
        params, None, [(r"c1/w", RW.SchemeChoice("block_punched", (8, 8)))])
    assert not report[0]["packed"]
    assert "does not divide" in report[0]["reason"]


def test_block_punched_on_non_conv_weight_skips():
    """block_punched mapped onto a 2-D FC weight must skip, not lower."""
    params = {"fc": {"w": jnp.ones((64, 64), jnp.float32)}}
    exec_params, report = compile_model(
        params, None, [(r"fc/w", RW.SchemeChoice("block_punched", (8, 8)))])
    assert not report[0]["packed"]
    assert "conv weight" in report[0]["reason"]
