"""Fault-matrix chaos suite (ISSUE 10 acceptance): every injector in
``repro.testing.faults`` is driven against the serving stack and must
yield (a) forward progress — the engine drains, nothing hangs, (b)
bit-identical tokens for every SURVIVING request versus an oracle run
that never admitted the faulty one, and (c) no silently wrong token —
a faulted request's emitted prefix still matches its healthy oracle,
because every fault is caught BEFORE its first garbage token.

All injections are seeded (`numpy.random.RandomState`), so the suite —
and the replay-determinism test at the bottom — sees the same faults,
events, and recoveries on every run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import reweighted as RW
from repro.core import validate as V
from repro.core.packed import DegradedLayer
from repro.launch.serve import SPARSE_SPEC
from repro.models import transformer as T
from repro.serve import artifacts as ART
from repro.serve import engine as E
from repro.serve.compile import (CompileSpec, compile_model, compiled_summary,
                                 degrade_invalid_layers)
from repro.serve.engine import ServingEngine, generate
from repro.serve.scheduler import (REASON_DEADLINE_EXPIRED,
                                   REASON_OVER_BUDGET, REASON_QUARANTINED,
                                   Request, Scheduler)
from repro.testing import faults as F
from repro.train.trainer import apply_masks

import jax


def _lm(arch):
    cfg = configs.get(arch, smoke=True)
    return T.init_lm(jax.random.PRNGKey(0), cfg), cfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


def _oracle(params, cfg, prompt, n_new):
    toks = generate(params, cfg, jnp.asarray([prompt], jnp.int32), n_new)
    return np.asarray(toks)[0].tolist()


@pytest.fixture(scope="module")
def dense_lm():
    return _lm("yi-9b")


@pytest.fixture(scope="module")
def packed_lm():
    """Masked + compiled smoke model (keep_dense=True so every packed
    layer carries the masked-dense fallback the degrade path needs)."""
    params, cfg = _lm("yi-9b")
    masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None, rate=0.6)
    params = apply_masks(params, masks)
    exec_params, report = compile_model(params, masks, SPARSE_SPEC,
                                        spec=CompileSpec(keep_dense=True))
    return cfg, params, masks, exec_params, report


def _counting(fn, counter):
    def wrapped(*a, **kw):
        counter.append(1)
        return fn(*a, **kw)
    return wrapped


# -- nan_slot: numerical quarantine ----------------------------------------

def test_nan_slot_quarantines_victim_only(dense_lm):
    """Poisoning one slot's cache quarantines THAT request (before any
    garbage token) and leaves every survivor bit-identical to a run that
    never admitted the victim."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [8, 12, 5])
    n_new = 6

    eng = ServingEngine(params, cfg, n_slots=3, seq_cap=32)
    rids = [eng.submit(p, n_new) for p in prompts]
    eng.step()                                   # admit all three
    victim = rids[1]
    vslot = eng.requests[victim].slot
    healthy_prefix = list(eng.requests[victim].tokens)
    rec = F.nan_slot(eng, vslot)
    assert rec.kind == "nan_slot"
    eng.run()

    assert eng.requests[victim].status == "quarantined"
    assert eng.stats["quarantined"] == 1
    assert eng.stats["finished"] == 2
    # the typed audit event names the slot and the reason
    assert ("quarantined", victim, vslot,
            REASON_QUARANTINED) in eng.sched.events
    # no silent wrong token: the victim kept only its pre-fault tokens,
    # which match its healthy oracle prefix
    vtok = eng.requests[victim].tokens
    assert vtok == healthy_prefix
    assert vtok == _oracle(params, cfg, prompts[1], n_new)[:len(vtok)]

    # never-admitted oracle: same engine, victim never submitted
    ref = ServingEngine(params, cfg, n_slots=3, seq_cap=32)
    ref_rids = [ref.submit(p, n_new) for i, p in enumerate(prompts)
                if i != 1]
    ref.run()
    survivors = [eng.requests[r].tokens for i, r in enumerate(rids)
                 if i != 1]
    assert survivors == [ref.requests[r].tokens for r in ref_rids]
    # and both equal the single-sequence generate oracle
    for toks, p in zip(survivors, [prompts[0], prompts[2]]):
        assert toks == _oracle(params, cfg, p, n_new)


def test_quarantined_slot_readmits_next_step(dense_lm):
    """Recovery is bounded: the slot a quarantine frees is refilled from
    the queue on the very next engine step."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [6, 9, 7], seed=2)
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32)
    rids = [eng.submit(p, 8) for p in prompts]
    eng.step()                                   # admit first two
    F.nan_slot(eng, eng.requests[rids[1]].slot)
    eng.step()                                   # probe fires -> evict
    assert eng.requests[rids[1]].status == "quarantined"
    q_step = eng.stats["steps"]
    eng.step()                                   # freed slot refills
    assert eng.requests[rids[2]].status == "running"
    assert eng.stats["steps"] - q_step == 1
    eng.run()
    assert eng.stats["finished"] == 2


def test_quarantine_probe_never_retraces(dense_lm, monkeypatch):
    """The fused finite probe rides the one batched decode executable:
    poisoning, quarantining, and re-admitting never retrace."""
    params, cfg = dense_lm
    traces = []
    monkeypatch.setattr(T, "decode_step_ragged",
                        _counting(T.decode_step_ragged, traces))
    E._JIT_CACHE.clear()
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32)
    rids = [eng.submit(p, 5) for p in _prompts(cfg, [8, 5, 12], seed=3)]
    eng.step()
    F.nan_slot(eng, eng.requests[rids[0]].slot)
    eng.run()
    assert eng.stats["quarantined"] == 1
    assert eng.stats["finished"] == 2
    assert len(traces) == 1


# -- corrupt_leaf: validate + degraded-mode fallback -----------------------

def test_bitflip_is_detected_by_validate(packed_lm):
    """The seeded bit-flip saturates exponent bits, so the new
    ``non_finite`` check is GUARANTEED to see it (a silent mantissa flip
    would be undetectable — the injector never produces one)."""
    _, _, _, exec_params, _ = packed_lm
    bad, rec = F.bitflip_packed_leaf(exec_params, seed=0)
    assert rec.kind == "corrupt_leaf"
    layers = dict(F._packed_layers(bad))
    with pytest.raises(V.LayoutError) as ei:
        V.validate_layout(layers[rec.target]["packed"], path=rec.target)
    assert ei.value.code in ("non_finite", "index_range")
    with pytest.raises(V.LayoutError):
        V.validate_tree(bad)
    # the input tree is skeleton-copied: the healthy original still passes
    assert V.validate_tree(exec_params) > 0


def test_bitflip_degrades_layer_to_masked_dense(packed_lm):
    """A corrupt packed layout degrades to the masked-dense path for THAT
    layer only: the engine serves tokens bit-identical to dense execution
    of the degraded tree, counts the layer, and annotates the report."""
    cfg, _, _, exec_params, report = packed_lm
    bad, rec = F.bitflip_packed_leaf(exec_params, seed=3)
    prompts = _prompts(cfg, [8, 5], seed=4)

    eng = ServingEngine(bad, cfg, n_slots=2, seq_cap=32, report=report)
    assert eng.stats["degraded_layers"] == 1
    # the marker replaced the layout at the faulted path
    degraded_node = eng.params
    for part in rec.target.split("/"):
        degraded_node = degraded_node[part]
    assert isinstance(degraded_node["packed"], DegradedLayer)
    assert degraded_node["packed"].code in ("non_finite", "index_range")
    # report row re-emitted with the degraded flag + structured reason
    rows = [r for r in eng.report if getattr(r, "degraded", None)]
    assert len(rows) == 1 and rows[0].path == f"{rec.target}/w"
    assert "masked-dense" in rows[0].reason
    assert "[DEGRADED" in compiled_summary(eng.report)

    rids = [eng.submit(p, 5) for p in prompts]
    eng.run()
    assert eng.stats["finished"] == 2
    for rid, p in zip(rids, prompts):
        assert eng.requests[rid].tokens == _oracle(eng.params, cfg, p, 5)


def test_corrupt_layout_without_dense_fallback_raises(packed_lm):
    """keep_dense=False leaves no masked-dense fallback: a corrupt layout
    must RAISE (fail loud), never degrade silently into wrong math."""
    _, _, _, exec_params, _ = packed_lm
    bad, rec = F.bitflip_packed_leaf(exec_params, seed=0)
    node = dict(F._packed_layers(bad))[rec.target]
    stripped = F._skeleton_swap(
        bad, node, {k: v for k, v in node.items() if k != "w"})
    with pytest.raises(V.LayoutError):
        degrade_invalid_layers(stripped)


def test_degraded_layer_marker_is_static_pytree():
    """DegradedLayer carries no array leaves — it is jit-static aux data,
    so swapping a layout for a marker changes the cache key (one retrace)
    instead of poisoning a compiled executable."""
    m = DegradedLayer(path="layers/attn/wq", code="non_finite", detail="x")
    leaves, treedef = jax.tree_util.tree_flatten(m)
    assert leaves == []
    assert jax.tree_util.tree_unflatten(treedef, leaves) == m
    assert hash(m) == hash(DegradedLayer(path="layers/attn/wq",
                                         code="non_finite", detail="x"))


# -- expired_deadline: deadlines, TTLs, bounded retry ----------------------

def test_running_deadline_evicts_with_typed_event(dense_lm):
    """A request past its ``deadline_steps`` budget is evicted at the
    top-of-step sweep with a typed event; its emitted prefix is still
    oracle-exact (bounded lateness, never wrong tokens)."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [8, 6], seed=5)
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32)
    doomed = eng.submit(prompts[0], 20, deadline_steps=2)
    other = eng.submit(prompts[1], 4)
    eng.run()
    dreq = eng.requests[doomed]
    assert dreq.status == "evicted"
    assert eng.stats["expired"] == 1
    assert any(e[0] == "evicted" and e[1] == doomed
               and e[-1] == REASON_DEADLINE_EXPIRED
               for e in eng.sched.events)
    # prefill token + 2 decode steps before the sweep fired
    assert len(dreq.tokens) == 3
    assert dreq.tokens == _oracle(params, cfg, prompts[0], 20)[:3]
    # the neighbor is untouched
    assert eng.requests[other].tokens == _oracle(params, cfg, prompts[1], 4)


def test_queue_ttl_expires_waiting_request(dense_lm):
    """A queued request whose TTL lapses is swept (typed ``expire`` event)
    before it can ever race into a slot; slot holders are unaffected."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [7, 9], seed=6)
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=32)
    hog = eng.submit(prompts[0], 8)
    brief = eng.submit(prompts[1], 8, queue_ttl=2)
    eng.run()
    assert eng.requests[brief].status == "expired"
    assert eng.requests[brief].tokens == []
    assert eng.stats["expired"] == 1
    assert any(e[0] == "expire" and e[1] == brief
               and e[2] == REASON_DEADLINE_EXPIRED
               for e in eng.sched.events)
    assert eng.requests[hog].tokens == _oracle(params, cfg, prompts[0], 8)


def test_expire_deadline_injector_evicts_running(dense_lm):
    """The chaos injector zeroes a RUNNING request's budget: next sweep
    evicts it and the freed slot keeps the engine making progress."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [8, 6, 5], seed=7)
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32)
    rids = [eng.submit(p, 6) for p in prompts]
    eng.step()
    rec = F.expire_deadline(eng, rids[0])
    assert rec.kind == "expired_deadline"
    eng.run()
    assert eng.requests[rids[0]].status == "evicted"
    assert eng.stats["finished"] == 2
    for rid, p in zip(rids[1:], prompts[1:]):
        assert eng.requests[rid].tokens == _oracle(params, cfg, p, 6)


def test_retry_backoff_is_bounded_and_audited():
    """Scheduler unit: a queue-full submission defers with exponential
    backoff (deterministic due steps), retries at most ``retries`` times,
    then rejects with the typed ``over_budget`` reason."""
    def scenario():
        sched = Scheduler(1, max_queue=1)
        r1 = Request(0, (1,), 4)
        r2 = Request(1, (2,), 4)
        r3 = Request(2, (3,), 4, retries=2, backoff=1)
        sched.submit(r1, 0)
        sched.admit(0)
        sched.submit(r2, 0)                  # queue now full
        assert sched.submit(r3, 0) == "deferred"
        assert sched.poll_retries(1) == []   # due at 1: defers again (due 3)
        assert r3.status == "deferred" and r3.attempts == 2
        rejected = sched.poll_retries(3)     # budget exhausted
        assert rejected == [r3] and r3.status == "rejected"
        return sched.events

    ev = scenario()
    assert ("defer", 2, 1, 1) in ev
    assert ("defer", 2, 2, 3) in ev
    assert ("reject", 2, REASON_OVER_BUDGET) in ev
    assert ev == scenario()                  # byte-identical replay


def test_retry_eventually_admits_when_queue_drains(dense_lm):
    """A deferred submission re-enters once its backoff elapses and the
    queue has space — the retry path ends in tokens, not starvation."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [6, 8], seed=8)
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=32, max_queue=1)
    first = eng.submit(prompts[0], 4)
    retry = eng.submit(prompts[1], 4, retries=3, backoff=1)
    assert eng.requests[retry].status == "deferred"
    eng.run()
    assert eng.requests[first].status == "finished"
    assert eng.requests[retry].status == "finished"
    assert eng.requests[retry].tokens == _oracle(params, cfg, prompts[1], 4)
    kinds = [e[0] for e in eng.sched.events if e[1] == retry]
    assert "defer" in kinds and "retry" in kinds


# -- crashed_publish: artifact-store fault tolerance -----------------------

def test_crashed_publish_staging_husk_is_ignored(tmp_path, packed_lm):
    """A writer killed mid-stage leaves a ``.tmp_*`` husk; the store's
    atomic-rename protocol means the published artifact stays warm."""
    cfg, params, masks, _, _ = packed_lm
    spec = CompileSpec(keep_dense=True)
    compile_model(params, masks, SPARSE_SPEC, spec=spec,
                  artifact_dir=tmp_path)        # cold pack + publish
    key = ART.model_digest(params, masks, SPARSE_SPEC, spec=spec)
    rec = F.crash_publish(tmp_path, key, stage="staging")
    assert rec.kind == "crashed_publish"
    warm = ART.load_grafted(tmp_path, key, params, keep_dense=True)
    assert warm is not None                      # husk never consulted


def test_crashed_publish_torn_artifact_repacks(tmp_path, packed_lm):
    """A torn final dir (no manifest) is treated as absent: load returns
    None and compile_model silently repays the fresh pack — tokens stay
    oracle-exact."""
    cfg, params, masks, exec_params, _ = packed_lm
    spec = CompileSpec(keep_dense=True)
    key = ART.model_digest(params, masks, SPARSE_SPEC, spec=spec)
    F.crash_publish(tmp_path, key, stage="torn")
    assert ART.load_grafted(tmp_path, key, params, keep_dense=True) is None
    repacked, report = compile_model(params, masks, SPARSE_SPEC, spec=spec,
                                     artifact_dir=tmp_path)
    assert any(r.packed for r in report)
    prompts = _prompts(cfg, [8, 5], seed=9)
    eng = ServingEngine(repacked, cfg, n_slots=2, seq_cap=32)
    rids = [eng.submit(p, 4) for p in prompts]
    eng.run()
    for rid, p in zip(rids, prompts):
        assert eng.requests[rid].tokens == _oracle(exec_params, cfg, p, 4)


# -- the full matrix, replayed ---------------------------------------------

def _chaos_run(params, cfg, prompts):
    """One deterministic multi-fault scenario: TTL expiry + deadline
    eviction + retry exhaustion + a mid-flight NaN slot, all at fixed
    steps.  Returns (events, token streams, stats)."""
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32, max_queue=2)
    rids = [
        eng.submit(prompts[0], 6),
        eng.submit(prompts[1], 6, deadline_steps=3),
        eng.submit(prompts[2], 6, queue_ttl=1),
        eng.submit(prompts[3], 6, retries=1, backoff=1),
        eng.submit(prompts[4], 6),
    ]
    eng.step()
    F.nan_slot(eng, eng.requests[rids[0]].slot)
    eng.run()
    toks = {r: list(eng.requests[r].tokens) for r in rids}
    status = {r: eng.requests[r].status for r in rids}
    return list(eng.sched.events), toks, status, dict(eng.stats)


def test_chaos_matrix_replays_identically(dense_lm):
    """The whole fault matrix in one run, twice: identical audit trails,
    token streams, terminal statuses, and counters — chaos is replayable,
    every request reaches a typed terminal state, and the engine drains."""
    params, cfg = dense_lm
    prompts = _prompts(cfg, [8, 6, 5, 7, 9], seed=10)
    a = _chaos_run(params, cfg, prompts)
    b = _chaos_run(params, cfg, prompts)
    assert a == b
    events, toks, status, stats = a
    terminal = {"finished", "quarantined", "evicted", "expired", "rejected"}
    assert set(status.values()) <= terminal
    assert status[0] == "quarantined"
    assert stats["quarantined"] == 1
    assert stats["finished"] >= 1
    # accounting closes: every admitted request left through a counted door
    assert (stats["finished"] + stats["quarantined"]
            + stats["evicted"] == stats["admitted"])
