"""Blocked Compressed Storage format tests (paper Fig 4)."""
import jax
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean container: deterministic example sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import bcs as BCS


def make(K=128, N=256, bk=32, bn=64, zero_frac=0.5, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = np.asarray(jax.random.normal(k1, (K, N)))
    keep = np.asarray(jax.random.uniform(k2, (K // bk, N // bn))) > zero_frac
    mask = np.repeat(np.repeat(keep, bk, 0), bn, 1).astype(np.float32)
    return w, mask


def test_roundtrip():
    w, mask = make()
    b = BCS.from_dense(w, mask, (32, 64))
    np.testing.assert_allclose(BCS.to_dense(b), w * mask)


def test_hierarchical_index_never_larger_when_rows_repeat():
    """Fig 4's point: identical per-row column patterns are deduped.
    (Needs >1 column per row for dedup to beat plain CSR — the paper's
    example rows share multi-entry column lists.)"""
    w, _ = make()
    mask = np.zeros_like(w)
    mask[:, :64] = 1.0          # every block row: columns {0, 2}
    mask[:, 128:192] = 1.0
    b = BCS.from_dense(w, mask, (32, 64))
    assert len(b.patterns) == 1
    assert b.index_bytes() < b.csr_index_bytes()


def test_uniform_csc_roundtrip():
    from repro.kernels.ref import uniform_to_dense
    w, mask = make(seed=3)
    b = BCS.from_dense(w, mask, (32, 64))
    vals, kidx, nnz = BCS.pad_to_uniform_csc(b)
    np.testing.assert_allclose(np.asarray(uniform_to_dense(vals, kidx, 128)),
                               w * mask)


def test_density_and_imbalance():
    w, mask = make(zero_frac=0.75, seed=5)
    b = BCS.from_dense(w, mask, (32, 64))
    assert 0 <= b.density <= 1
    assert BCS.load_imbalance(b) >= 1.0


@settings(max_examples=15, deadline=None)
@given(bk=st.sampled_from([16, 32]), bn=st.sampled_from([32, 64]),
       zf=st.floats(0, 0.9), seed=st.integers(0, 30))
def test_roundtrip_property(bk, bn, zf, seed):
    w, mask = make(bk=bk, bn=bn, zero_frac=zf, seed=seed)
    b = BCS.from_dense(w, mask, (bk, bn))
    np.testing.assert_allclose(BCS.to_dense(b), w * mask)
    # hierarchical metadata never exceeds plain CSR
    assert b.index_bytes() <= b.csr_index_bytes() + 4 * len(b.row_ptr)
