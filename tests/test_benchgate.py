"""The benchmark-regression gate itself (benchmarks.compare): regressions
fail, noise-tolerant wall metrics get the loose threshold, and every way a
baseline/fresh file can be missing or corrupt produces a ONE-LINE failure
pointing at ``--update-baselines`` — never a traceback."""
import json

import pytest

from benchmarks import compare as C


def payload(rows):
    return {"bench": "bench_x", "rows": [
        {"name": n, "us_per_call": 1.0, "derived": d} for n, d in rows]}


def write(path, obj):
    path.write_text(json.dumps(obj) if not isinstance(obj, str) else obj)


@pytest.fixture
def gate(tmp_path, monkeypatch):
    """Isolated baseline dir + fresh dir; returns a main() runner."""
    base = tmp_path / "baselines"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    monkeypatch.setattr(C, "BASELINE_DIR", base)

    def run():
        return C.main(["--fresh-dir", str(fresh)])
    return base, fresh, run


GOOD = payload([("row_a", "modeled_speedup=4.00x;flops_saved=0.60"),
                ("coldstart,x", "artifact_warm_speedup=50.00x")])


def test_identical_files_pass(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_x.json", GOOD)
    assert run() == 0
    assert "gate passed" in capsys.readouterr().out


def test_regression_fails(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_x.json",
          payload([("row_a", "modeled_speedup=2.00x;flops_saved=0.60"),
                   ("coldstart,x", "artifact_warm_speedup=50.00x")]))
    assert run() == 1
    assert "regressed" in capsys.readouterr().err


def test_warm_speedup_rides_loose_wall_threshold(gate):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    # 50x -> 30x: a 40% swing, above --threshold but under --wall-threshold
    write(fresh / "BENCH_x.json",
          payload([("row_a", "modeled_speedup=4.00x;flops_saved=0.60"),
                   ("coldstart,x", "artifact_warm_speedup=30.00x")]))
    assert run() == 0


def test_missing_fresh_file_points_at_update_baselines(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_renamed_away.json", GOOD)
    assert run() == 1
    err = capsys.readouterr().err
    assert err.count("FAIL:") == 1
    assert "missing" in err and "--update-baselines" in err


def test_corrupt_baseline_is_one_line_not_traceback(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", "{definitely not json")
    write(fresh / "BENCH_x.json", GOOD)
    assert run() == 1
    err = capsys.readouterr().err
    assert err.count("FAIL:") == 1
    assert "corrupt" in err and "--update-baselines" in err


def test_baseline_rows_missing_derived_key(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", {"rows": [{"name": "row_a"}]})
    write(fresh / "BENCH_x.json", GOOD)
    assert run() == 1
    assert "corrupt" in capsys.readouterr().err


def test_corrupt_fresh_file_fails_cleanly(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_x.json", "[1, 2")
    assert run() == 1
    err = capsys.readouterr().err
    assert "fresh" in err and "corrupt" in err


def test_vanished_metric_fails(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_x.json",
          payload([("row_a", "modeled_speedup=4.00x"),
                   ("coldstart,x", "artifact_warm_speedup=50.00x")]))
    assert run() == 1
    assert "vanished" in capsys.readouterr().err


def test_fresh_without_baseline_is_a_note_not_a_failure(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_x.json", GOOD)
    write(fresh / "BENCH_new_suite.json", GOOD)
    assert run() == 0
    out = capsys.readouterr().out
    assert "no committed baseline" in out and "BENCH_new_suite" in out


def test_tok_per_s_gates_higher_better_at_wall_threshold(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("serving,B8", "tok_per_s=2000.0")]))
    # 35% drop: above --threshold, under --wall-threshold -> passes
    write(fresh / "BENCH_x.json",
          payload([("serving,B8", "tok_per_s=1300.0")]))
    assert run() == 0
    # 60% collapse trips the wall gate
    write(fresh / "BENCH_x.json",
          payload([("serving,B8", "tok_per_s=800.0")]))
    assert run() == 1
    assert "regressed" in capsys.readouterr().err


def test_batch_speedup_is_a_wall_metric(gate):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("serving,scaling", "batch_speedup=4.00x")]))
    write(fresh / "BENCH_x.json",
          payload([("serving,scaling", "batch_speedup=3.00x")]))
    assert run() == 0          # 25% wall swing tolerated
    write(fresh / "BENCH_x.json",
          payload([("serving,scaling", "batch_speedup=1.50x")]))
    assert run() == 1


def test_mean_occupancy_gates_strictly(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("serving,rate1", "mean_occupancy=0.70")]))
    # deterministic scheduler metric: a 20% drop fails at the strict 10%
    write(fresh / "BENCH_x.json",
          payload([("serving,rate1", "mean_occupancy=0.56")]))
    assert run() == 1
    assert "regressed" in capsys.readouterr().err
    write(fresh / "BENCH_x.json",
          payload([("serving,rate1", "mean_occupancy=0.68")]))
    assert run() == 0


def test_memory_metric_gates_lower_is_better(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json", payload([("row_a", "peak_mb=10.00")]))
    write(fresh / "BENCH_x.json", payload([("row_a", "peak_mb=14.00")]))
    assert run() == 1
    assert "grew" in capsys.readouterr().err
    write(fresh / "BENCH_x.json", payload([("row_a", "peak_mb=8.00")]))
    assert run() == 0                       # shrinking is never a failure


def test_shard_balance_gates_lower_is_better_strictly(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("shard,tp=4", "shard_balance=1.05")]))
    # deterministic layout accounting: a 15% growth fails at the strict 10%
    write(fresh / "BENCH_x.json",
          payload([("shard,tp=4", "shard_balance=1.21")]))
    assert run() == 1
    assert "lower-is-better" in capsys.readouterr().err
    write(fresh / "BENCH_x.json",
          payload([("shard,tp=4", "shard_balance=1.00")]))
    assert run() == 0                       # perfect balance never fails


def test_tp_speedup_is_a_wall_metric(gate):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("tp_model,fc", "tp_speedup=4.00x")]))
    write(fresh / "BENCH_x.json",
          payload([("tp_model,fc", "tp_speedup=3.00x")]))
    assert run() == 0          # 25% padding swing tolerated
    write(fresh / "BENCH_x.json",
          payload([("tp_model,fc", "tp_speedup=1.50x")]))
    assert run() == 1          # scaling collapse still trips the gate


def test_degraded_throughput_ratio_is_a_wall_metric(gate):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("faults,degraded", "degraded_throughput_ratio=1.10")]))
    # plain-float ratio (no 'x' suffix) still parses and gates
    write(fresh / "BENCH_x.json",
          payload([("faults,degraded", "degraded_throughput_ratio=0.90")]))
    assert run() == 0          # 18% wall swing tolerated at 50%
    write(fresh / "BENCH_x.json",
          payload([("faults,degraded", "degraded_throughput_ratio=0.40")]))
    assert run() == 1          # degraded mode collapsing trips the gate


def test_recovery_steps_gates_lower_is_better_strictly(gate, capsys):
    base, fresh, run = gate
    write(base / "BENCH_x.json",
          payload([("faults,recovery", "recovery_steps=1")]))
    # deterministic scheduler replay: ANY growth beyond 10% fails
    write(fresh / "BENCH_x.json",
          payload([("faults,recovery", "recovery_steps=2")]))
    assert run() == 1
    assert "lower-is-better" in capsys.readouterr().err
    write(fresh / "BENCH_x.json",
          payload([("faults,recovery", "recovery_steps=1")]))
    assert run() == 0
