"""Continuous-batching serving engine tests: ragged-batch decode is
bit-identical to N independent ``generate`` calls (the oracle) across all
served families and the packed-kernel path, slot reuse leaks no stale KV,
the scheduler replays deterministically, and neither ``generate`` nor the
engine's batched step ever retraces after the first call."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import reweighted as RW
from repro.models import transformer as T
from repro.serve import engine as E
from repro.serve import kvcache as KV
from repro.serve.compile import CompileSpec, compile_model
from repro.serve.engine import ServingEngine, generate
from repro.serve.scheduler import Request, Scheduler
from repro.train.trainer import apply_masks

SMOKE = {"dense": "yi-9b", "moe": "mixtral-8x7b", "ssm": "mamba2-1.3b",
         "hybrid": "hymba-1.5b"}


def _lm(arch, **over):
    cfg = configs.get(arch, smoke=True)
    if over:
        cfg = cfg.replace(**over)
    return T.init_lm(jax.random.PRNGKey(0), cfg), cfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


def _oracle(params, cfg, prompt, n_new):
    toks = generate(params, cfg, jnp.asarray([prompt], jnp.int32), n_new)
    return np.asarray(toks)[0].tolist()


def _assert_engine_matches_oracle(params, cfg, prompts, n_new, n_slots):
    eng = ServingEngine(params, cfg, n_slots=n_slots, seq_cap=32)
    rids = [eng.submit(p, n_new) for p in prompts]
    eng.run()
    for rid, p in zip(rids, prompts):
        req = eng.requests[rid]
        assert req.status == "finished"
        assert req.tokens == _oracle(params, cfg, p, n_new), (
            f"rid={rid} prompt_len={len(p)} diverged from generate")
    return eng


# -- ragged-batch bit-identity oracle, all served families -------------------

@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_engine_bit_identical_to_generate(family):
    """A batch of mixed-length requests sharing slots decodes exactly the
    tokens N independent single-sequence ``generate`` calls produce."""
    params, cfg = _lm(SMOKE[family])
    prompts = _prompts(cfg, [8, 12, 5])
    eng = _assert_engine_matches_oracle(params, cfg, prompts, 6, n_slots=2)
    # 3 requests through 2 slots: the third reused an evicted slot
    assert eng.stats["finished"] == 3
    assert eng.stats["tokens"] == sum(len(eng.requests[r].tokens)
                                      for r in eng.requests)


def test_engine_packed_kernel_path():
    """The oracle holds on compile_model-packed params — the batched
    launch hits the real Pallas BCS kernels, not a dense fallback."""
    params, cfg = _lm(SMOKE["dense"])
    from repro.launch.serve import SPARSE_SPEC
    masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None, rate=0.6)
    params = apply_masks(params, masks)
    params, _ = compile_model(params, masks, SPARSE_SPEC,
                              spec=CompileSpec(keep_dense=False))
    _assert_engine_matches_oracle(params, cfg, _prompts(cfg, [9, 6]), 5,
                                  n_slots=2)


def test_engine_sliding_window_parity():
    """Per-slot ring capacities reproduce ``generate``'s drop-oldest
    window semantics when prompts straddle the window length."""
    params, cfg = _lm(SMOKE["dense"], sliding_window=8)
    # one prompt longer than the window (ring wraps), one shorter
    _assert_engine_matches_oracle(params, cfg, _prompts(cfg, [12, 5]), 6,
                                  n_slots=2)


# -- slot hygiene ------------------------------------------------------------

def test_slot_reuse_leaks_no_stale_kv():
    """Back-to-back occupants of the SAME slot each match their oracle:
    the second request decodes as if the first never existed."""
    params, cfg = _lm(SMOKE["dense"])
    p1, p2 = _prompts(cfg, [11, 7], seed=3)
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=32)
    r1 = eng.submit(p1, 6)
    r2 = eng.submit(p2, 6)
    eng.run()
    assert eng.requests[r1].tokens == _oracle(params, cfg, p1, 6)
    assert eng.requests[r2].tokens == _oracle(params, cfg, p2, 6)
    # both really went through slot 0, serially
    admits = [e for e in eng.sched.events if e[0] == "admit"]
    assert [e[2] for e in admits] == [0, 0]


def test_cleared_slot_positions_invalidated():
    """Eviction leaves the slot row with every position INVALID — the
    dead history is structurally unreachable even before the next
    admission's zero-fill."""
    params, cfg = _lm(SMOKE["dense"])
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=16)
    eng.submit(_prompts(cfg, [6])[0], 3)
    eng.run()
    pos = np.asarray(eng.cache["kv"]["pos"])
    assert (pos == KV.INVALID_POS).all(), "evicted slot kept live positions"


def test_stop_token_ends_request_early():
    params, cfg = _lm(SMOKE["dense"])
    prompt = _prompts(cfg, [8])[0]
    ref = _oracle(params, cfg, prompt, 8)
    stop = ref[3]
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=32)
    rid = eng.submit(prompt, 8, stop_token=stop)
    eng.run()
    # truncated at the FIRST emission of the stop token
    cut = ref.index(stop) + 1
    assert eng.requests[rid].tokens == ref[:cut]
    assert len(eng.requests[rid].tokens) < 8


# -- scheduler ---------------------------------------------------------------

def test_scheduler_replays_deterministically():
    """Same submissions -> byte-identical event audit trails."""
    def run_once():
        sched = Scheduler(2)
        reqs = [Request(i, (1,), 3, arrival=i // 2) for i in range(5)]
        for r in reqs:
            sched.submit(r)
        now = 0
        while sched.has_work():
            while sched.admit(now) is not None:
                pass
            for _, r in sched.active():
                r.tokens.append(0)
                if r.done():
                    sched.release(r)
            now += 1
        return sched.events
    assert run_once() == run_once()


def test_scheduler_admits_lowest_slot_and_gates_on_arrival():
    sched = Scheduler(3)
    early = Request(0, (1,), 2, arrival=0)
    late = Request(1, (1,), 2, arrival=5)
    sched.submit(early)
    sched.submit(late)
    slot, req = sched.admit(now=0)
    assert (slot, req.rid) == (0, 0)
    # head-of-line: rid 1 hasn't arrived, so nothing admits at now=0
    assert sched.admit(now=0) is None
    assert sched.admit(now=5) == (1, late)
    sched.release(early)
    assert sched.active() == [(1, late)]


def test_over_budget_prompt_rejected_not_queued():
    params, cfg = _lm(SMOKE["dense"])
    eng = ServingEngine(params, cfg, n_slots=1, seq_cap=8)
    rid = eng.submit(list(range(1, 20)), 4)     # prompt 19 > seq_cap 8
    assert eng.requests[rid].status == "rejected"
    assert eng.stats["rejected"] == 1
    assert not eng.sched.has_work()
    ok = eng.submit(_prompts(cfg, [4])[0], 3)
    eng.run()
    assert eng.requests[ok].status == "finished"
    assert eng.stats["evicted"] == 0


def test_occupancy_and_counter_accounting():
    params, cfg = _lm(SMOKE["dense"])
    eng = ServingEngine(params, cfg, n_slots=4, seq_cap=32)
    for p in _prompts(cfg, [6, 6]):
        eng.submit(p, 4)
    eng.run()
    assert eng.stats["admitted"] == eng.stats["finished"] == 2
    assert eng.stats["evicted"] == 0
    assert 0.0 < eng.mean_occupancy() <= 0.5    # 2 busy of 4 slots


# -- retrace regression ------------------------------------------------------

def _counting(fn, counter):
    def wrapped(*a, **kw):
        counter.append(1)
        return fn(*a, **kw)
    return wrapped


def test_generate_traces_once_across_requests(monkeypatch):
    """Two same-shape generate calls share one compiled decode loop: the
    per-request retrace would otherwise dominate small-request serving."""
    params, cfg = _lm(SMOKE["dense"])
    traces = []
    monkeypatch.setattr(T, "decode_loop", _counting(T.decode_loop, traces))
    E._JIT_CACHE.clear()
    toks = jnp.asarray(_prompts(cfg, [8, 8], seed=1), jnp.int32)
    generate(params, cfg, toks[:1], 4)
    generate(params, cfg, toks[1:], 4)
    assert len(traces) == 1


def test_engine_step_traces_once_across_admissions(monkeypatch):
    """Admission, eviction, and slot reuse never retrace the batched
    decode step — its shapes are pinned by (n_slots, seq_cap)."""
    params, cfg = _lm(SMOKE["dense"])
    traces = []
    monkeypatch.setattr(T, "decode_step_ragged",
                        _counting(T.decode_step_ragged, traces))
    E._JIT_CACHE.clear()
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32)
    for i, p in enumerate(_prompts(cfg, [8, 5, 12])):
        eng.submit(p, 4, arrival=i)             # staggered arrivals
    eng.run()
    assert eng.stats["finished"] == 3
    assert len(traces) == 1
