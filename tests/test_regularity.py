"""Mask-generator unit + property tests (paper §2.1.1 / §4.1 regularities)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean container: deterministic example sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import regularity as R


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


class TestUnstructured:
    def test_density_matches_rate(self):
        w = rand((64, 128))
        m = R.unstructured_mask(w, rate=0.75)
        assert abs(R.density(m) - 0.25) < 0.02

    def test_keeps_largest(self):
        w = jnp.asarray([[1.0, 0.1], [5.0, 0.01]])
        m = R.unstructured_mask(w, rate=0.5)
        assert m[1, 0] == 1 and m[1, 1] == 0


class TestStructured:
    def test_row_prunes_whole_rows(self):
        w = rand((32, 64))
        m = R.structured_mask(w, rate=0.5, axis="row")
        rowsum = jnp.sum(m, axis=1)
        assert set(np.asarray(rowsum).tolist()) <= {0.0, 64.0}

    def test_col_prunes_whole_cols(self):
        w = rand((32, 64))
        m = R.structured_mask(w, rate=0.5, axis="col")
        colsum = jnp.sum(m, axis=0)
        assert set(np.asarray(colsum).tolist()) <= {0.0, 32.0}


class TestBlock:
    def test_block_rows_within_blocks(self):
        """§4.1.1: pruning decisions are independent PER BLOCK — each
        block's mask is a row-subset x col-subset pattern."""
        w = rand((64, 128))
        m = R.block_mask(w, (16, 32), rate=0.6, mode="row")
        mb = np.asarray(R._to_blocks(m, 16, 32))
        for i in range(mb.shape[0]):
            for j in range(mb.shape[1]):
                rows = mb[i, j].sum(axis=1)
                assert set(rows.tolist()) <= {0.0, 32.0}

    def test_per_block_rates_differ(self):
        """Auto per-block compression: the global threshold yields
        different kept-row counts across blocks (the paper's point)."""
        w = np.asarray(rand((64, 128))).copy()
        w[:16, :32] *= 10.0  # one block much more important
        m = R.block_mask(jnp.asarray(w), (16, 32), rate=0.5, mode="row")
        mb = np.asarray(R._to_blocks(m, 16, 32))
        kept = mb.sum(axis=(2, 3)) / 32
        assert kept[0, 0] == 16  # the boosted block keeps all rows
        assert kept.min() < 16

    def test_block1x1_equals_unstructured(self):
        """Fig 5: block size 1x1 == unstructured pruning."""
        w = rand((16, 16))
        m1 = R.block_mask(w, (1, 1), rate=0.5, mode="row")
        m2 = R.unstructured_mask(w, rate=0.5)
        assert jnp.allclose(m1, m2)

    def test_whole_matrix_block_equals_structured(self):
        """Fig 5: block == whole matrix -> structured pruning."""
        w = rand((16, 32))
        m1 = R.block_mask(w, (16, 32), rate=0.5, mode="row")
        m2 = R.structured_mask(w, rate=0.5, axis="row")
        assert jnp.allclose(m1, m2)


class TestBlockPunched:
    def test_same_punch_across_block(self):
        """§4.1.2: same intra-kernel locations pruned for ALL kernels in a
        block."""
        w = rand((8, 8, 3, 3))
        m = np.asarray(R.block_punched_mask(w, (4, 4), rate=0.5))
        blk = m[:4, :4]          # one block
        first = blk[0, 0]
        assert (blk == first[None, None]).all()

    def test_batch_leading_dims(self):
        w = rand((4, 64, 128))    # e.g. stacked MoE experts
        m = R.block_mask(w, (16, 32), rate=0.5, mode="row")
        assert m.shape == w.shape


class TestPattern:
    def test_four_entries_per_kernel(self):
        w = rand((8, 4, 3, 3))
        m = R.pattern_mask(w)
        per_kernel = jnp.sum(m, axis=(-1, -2))
        assert (per_kernel == 4).all()

    def test_patterns_from_fixed_set(self):
        w = rand((8, 4, 3, 3))
        m = np.asarray(R.pattern_mask(w)).reshape(-1, 9)
        pset = {tuple(p.reshape(-1).tolist()) for p in np.asarray(R.PATTERN_SET)}
        for k in m:
            assert tuple(k.tolist()) in pset

    def test_connectivity_prunes_kernels(self):
        w = rand((8, 8, 3, 3))
        m = R.pattern_mask(w, connectivity_rate=0.5)
        per_kernel = np.asarray(jnp.sum(m, axis=(-1, -2)))
        assert set(per_kernel.reshape(-1).tolist()) <= {0.0, 4.0}
        assert (per_kernel == 0).mean() == pytest.approx(0.5, abs=0.1)

    def test_rejects_non_3x3(self):
        with pytest.raises(AssertionError):
            R.pattern_mask(rand((4, 4, 5, 5)))


@settings(max_examples=20, deadline=None)
@given(pb=st.sampled_from([4, 8, 16]), qb=st.sampled_from([8, 16, 32]),
       rate=st.floats(0.1, 0.9), seed=st.integers(0, 100))
def test_block_mask_density_property(pb, qb, rate, seed):
    """Property: block mask density is within tolerance of (1 - rate)."""
    w = rand((64, 128), seed)
    m = R.block_mask(w, (pb, qb), rate=rate, mode="row")
    assert R.density(m) == pytest.approx(1 - rate, abs=0.15)


@settings(max_examples=20, deadline=None)
@given(scheme=st.sampled_from(["unstructured", "structured_row",
                               "structured_col", "block", "block_row"]),
       seed=st.integers(0, 50))
def test_mask_is_binary_property(scheme, seed):
    w = rand((32, 64), seed)
    m = np.asarray(R.make_mask(w, scheme, block=(8, 16), rate=0.5))
    assert set(np.unique(m).tolist()) <= {0.0, 1.0}


def test_legal_blocks_divisibility():
    for (p, q) in R.legal_blocks(4096, 11008):
        assert 4096 % p == 0 and 11008 % q == 0
