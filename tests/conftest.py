"""Test-session device setup.

The sharding suites (``test_sharding.py``, ``test_distributed.py``) need
several devices; on the CPU-only CI runner those are faked with XLA's
host-platform device-count flag.  The flag must land in ``XLA_FLAGS``
BEFORE jax initializes its backends, so it is appended here — conftest
imports before any test module touches jax — and guarded so an explicit
user/CI setting wins.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"

if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()
