"""Distribution layer tests: checkpoint/restart (incl. corruption and
wrong-tree restores -> structured CheckpointError), elastic meshes +
replica warm restarts through the artifact store, gradient compression,
sharding spec coverage — all CPU-runnable."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import checkpoint as CKPT
from repro.distributed import sharding as SH
from repro.distributed.elastic import (choose_mesh_shape, replica_restore,
                                       StragglerMonitor)
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        CKPT.save(tmp_path, 7, tree)
        restored, step = CKPT.restore(tmp_path, tree)
        assert step == 7
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.asarray(tree["a"]))

    def test_latest_complete_wins(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        CKPT.save(tmp_path, 5, tree)
        CKPT.save(tmp_path, 9, {"a": jnp.ones((2,))})
        # a torn checkpoint without manifest must be ignored
        (tmp_path / "step_00000011").mkdir()
        restored, step = CKPT.restore(tmp_path, tree)
        assert step == 9
        assert float(restored["a"][0]) == 1.0

    def test_restore_with_resharding(self, tmp_path):
        mesh = make_local_mesh()
        tree = {"w": jnp.arange(8.0).reshape(2, 4)}
        CKPT.save(tmp_path, 1, tree)
        sh = {"w": jax.sharding.NamedSharding(mesh, P(None, "model"))}
        restored, _ = CKPT.restore(tmp_path, tree, shardings=sh)
        assert restored["w"].sharding.spec == P(None, "model")

    def test_empty_dir(self, tmp_path):
        restored, step = CKPT.restore(tmp_path / "nope", {"a": jnp.zeros(1)})
        assert restored is None and step is None


class TestCheckpointFaults:
    """Every way a restore can go wrong raises a CheckpointError that
    names the offending file/param — never a raw KeyError or a shape
    blow-up deep inside tree_unflatten."""

    TREE = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}

    def _saved(self, tmp_path):
        CKPT.save(tmp_path, 3, self.TREE)
        return tmp_path / "step_00000003"

    def test_restore_into_bigger_tree_names_missing_param(self, tmp_path):
        self._saved(tmp_path)
        bigger = {**self.TREE, "extra": {"w": jnp.zeros((2, 2)),
                                         "v": jnp.zeros(3)}}
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, bigger)
        assert ei.value.code == "missing_key"
        assert "extra/v" in str(ei.value) and "+1 more" in str(ei.value)

    def test_restore_into_smaller_tree_names_unexpected_param(
            self, tmp_path):
        self._saved(tmp_path)
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, {"a": self.TREE["a"]})
        assert ei.value.code == "unexpected_key"
        assert "b/c" in str(ei.value)

    def test_restore_wrong_shape_names_param(self, tmp_path):
        self._saved(tmp_path)
        wrong = {"a": jnp.zeros((3, 2)), "b": {"c": self.TREE["b"]["c"]}}
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, wrong)
        assert ei.value.code == "shape" and "'a'" in str(ei.value)

    def test_restore_wrong_dtype_kind_names_param(self, tmp_path):
        self._saved(tmp_path)
        wrong = {"a": self.TREE["a"], "b": {"c": jnp.ones((4,))}}
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, wrong)
        assert ei.value.code == "dtype" and "b/c" in str(ei.value)

    def test_bitflip_in_shard_fails_checksum(self, tmp_path):
        d = self._saved(tmp_path)
        shard = d / "shard_0.npz"
        raw = bytearray(shard.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        shard.write_bytes(bytes(raw))
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, self.TREE)
        assert ei.value.code == "checksum"

    def test_truncated_shard_fails_size_check(self, tmp_path):
        d = self._saved(tmp_path)
        shard = d / "shard_0.npz"
        shard.write_bytes(shard.read_bytes()[:-16])
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, self.TREE)
        assert ei.value.code == "checksum" and "truncated" in str(ei.value)

    def test_missing_shard_file(self, tmp_path):
        d = self._saved(tmp_path)
        (d / "shard_0.npz").unlink()
        with pytest.raises(CKPT.CheckpointError) as ei:
            CKPT.restore(tmp_path, self.TREE)
        assert ei.value.code == "missing_file"

    def test_bf16_roundtrip_recasts(self, tmp_path):
        tree = {"w": jnp.linspace(-2, 2, 8).astype(jnp.bfloat16)}
        CKPT.save(tmp_path, 1, tree)
        restored, _ = CKPT.restore(tmp_path, tree)
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                      np.asarray(tree["w"], np.float32))


class TestElastic:
    def test_shrink_keeps_model_parallel(self):
        shape, axes = choose_mesh_shape(512, model_parallel=16, want_pods=2)
        assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
        # lose a pod -> single-pod mesh
        shape, axes = choose_mesh_shape(256, model_parallel=16)
        assert shape == (16, 16)
        # heavily degraded: model parallel folds down
        shape, axes = choose_mesh_shape(24, model_parallel=16)
        assert shape[0] * shape[1] <= 24 and 24 % shape[1] == 0

    def test_straggler_monitor(self):
        m = StragglerMonitor(k=3.0)
        for _ in range(10):
            assert not m.observe(1.0)
        assert m.observe(10.0)

    def test_replica_restore_warm_starts_from_artifacts(self, tmp_path):
        """Replica restart: checkpoint restore + artifact warm start give
        the same exec tree as a fresh cold compile, with zero packing on
        the second (restarted) replica."""
        from repro.core import reweighted as RW
        from repro.kernels import ops
        from repro.train.trainer import apply_masks

        spec = [(r"ffn/(gate|up)/w", RW.SchemeChoice("block", (16, 16)))]
        params = {"blk": {"ffn": {
            "gate": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                            (64, 96), jnp.float32)},
            "up": {"w": jax.random.normal(jax.random.PRNGKey(1),
                                          (64, 96), jnp.float32)}}}}
        masks = RW.random_block_masks(params, spec, (16, 16),
                                      keep_prob=0.4)
        pm = apply_masks(params, masks)
        ckpt, store = tmp_path / "ckpt", tmp_path / "art"
        CKPT.save(ckpt, 12, pm)

        ops.clear_pack_cache()
        exec1, rep1, step1 = replica_restore(ckpt, pm, mapping=spec,
                                             artifact_dir=store)
        assert step1 == 12 and any(r["packed"] for r in rep1)
        # restarted replica: same call, artifact now published
        ops.clear_pack_cache()
        misses = ops.pack_cache_stats()["misses"]
        exec2, rep2, step2 = replica_restore(ckpt, pm, mapping=spec,
                                             artifact_dir=store)
        assert step2 == 12
        assert ops.pack_cache_stats()["misses"] == misses  # no repack
        l1 = jax.tree_util.tree_leaves(exec1)
        l2 = jax.tree_util.tree_leaves(exec2)
        assert len(l1) == len(l2)
        for x, y in zip(l1, l2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_replica_restore_empty_dir(self, tmp_path):
        assert replica_restore(tmp_path / "none", {"a": jnp.zeros(1)}) == \
            (None, None, None)

    def test_replica_restore_survives_double_fault(self, tmp_path):
        """Corrupt NEWEST checkpoint AND corrupt artifact in the same
        start: the replica falls back to the next older complete step,
        repacks fresh, and serves a tree bit-identical to a cold compile
        of the surviving step.  A pinned corrupt step still raises."""
        from repro.core import reweighted as RW
        from repro.kernels import ops
        from repro.serve.compile import compile_model
        from repro.testing import faults as F
        from repro.train.trainer import apply_masks

        spec = [(r"ffn/(gate|up)/w", RW.SchemeChoice("block", (16, 16)))]
        params = {"blk": {"ffn": {
            "gate": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                            (64, 96), jnp.float32)},
            "up": {"w": jax.random.normal(jax.random.PRNGKey(1),
                                          (64, 96), jnp.float32)}}}}
        masks = RW.random_block_masks(params, spec, (16, 16),
                                      keep_prob=0.4)
        pm = apply_masks(params, masks)
        ckpt, store = tmp_path / "ckpt", tmp_path / "art"
        CKPT.save(ckpt, 10, pm)
        CKPT.save(ckpt, 12, pm)
        # healthy start publishes the artifact
        _, _, step0 = replica_restore(ckpt, pm, mapping=spec,
                                      artifact_dir=store)
        assert step0 == 12
        # fault 1: bit-flip the newest checkpoint's shard (checksum fail)
        shard = ckpt / "step_00000012" / "shard_0.npz"
        raw = bytearray(shard.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        shard.write_bytes(bytes(raw))
        # fault 2: tear the published artifact (writer crash, no manifest)
        keys = [d.name for d in store.iterdir()
                if not d.name.startswith(".")]
        assert len(keys) == 1
        F.crash_publish(store, keys[0], stage="torn")

        ops.clear_pack_cache()
        misses = ops.pack_cache_stats()["misses"]
        exec2, rep2, step2 = replica_restore(ckpt, pm, mapping=spec,
                                             artifact_dir=store)
        assert step2 == 10                      # older step substituted
        assert ops.pack_cache_stats()["misses"] > misses  # fresh repack
        assert any(r["packed"] for r in rep2)
        restored, _ = CKPT.restore(ckpt, pm, step=10)
        cold, _ = compile_model(restored, None, spec)
        l2, lc = (jax.tree_util.tree_leaves(t) for t in (exec2, cold))
        assert len(l2) == len(lc)
        for x, y in zip(l2, lc):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # an explicitly pinned corrupt step raises — never substitutes
        with pytest.raises(CKPT.CheckpointError):
            replica_restore(ckpt, pm, mapping=spec, step=12,
                            artifact_dir=store)


class TestGradCompression:
    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        q, scale = SH.quantize_int8(x, jax.random.PRNGKey(1))
        err = jnp.abs(SH.dequantize_int8(q, scale) - x)
        assert float(err.max()) <= scale * 1.01
        assert q.dtype == jnp.int8           # 4x wire reduction

    def test_compressed_allreduce_unbiased(self):
        """Stochastic rounding: mean error over many keys ~ 0."""
        x = jax.random.normal(jax.random.PRNGKey(0), (256,))
        outs = []
        for s in range(20):
            q, sc = SH.quantize_int8(x, jax.random.PRNGKey(s))
            outs.append(SH.dequantize_int8(q, sc))
        bias = jnp.abs(jnp.mean(jnp.stack(outs), 0) - x)
        assert float(bias.mean()) < float(jnp.abs(x).mean()) * 0.01 + 1e-3

    def test_compressed_allreduce_in_shard_map(self):
        from jax.experimental.shard_map import shard_map
        mesh = make_local_mesh()
        x = jnp.ones((4, 8))

        def f(xs):
            return SH.compressed_allreduce(xs, jax.random.PRNGKey(0),
                                           "data")
        y = shard_map(f, mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)


class TestShardingSpecs:
    @pytest.mark.parametrize("arch", list(configs.ALIASES))
    def test_param_specs_cover_all_leaves(self, arch):
        """Every param leaf gets a full-rank spec whose sharded dims divide
        the global shape — exactly what pjit will verify at 256 devices."""
        cfg = configs.get(arch)
        params = jax.eval_shape(
            lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
        mesh_axes = {"data": 16, "model": 16}
        # use abstract mesh sizes (no need for 256 real devices)
        from repro.distributed.sharding import param_rules
        from repro.models.module import spec_from_rules, path_str

        class FakeMesh:
            shape = mesh_axes
        specs = spec_from_rules(params, param_rules(cfg, FakeMesh()))
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert len(spec) == leaf.ndim, (path_str(path), spec)
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh_axes.get(a, 1)
                assert dim % size == 0, \
                    f"{path_str(path)}: {leaf.shape} vs {spec}"

    def test_sharded_train_step_runs_on_local_mesh(self):
        """The exact sharded code path (constraints included) on 1 CPU."""
        from repro.data.pipeline import synthetic_batch
        from repro.train.trainer import make_train_step
        cfg = configs.get("yi-9b", smoke=True)
        mesh = make_local_mesh()
        dist = SH.make_dist(mesh, cfg, 4)
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        opt_init, step = make_train_step(cfg, dist=dist)
        opt = opt_init(params)
        b = synthetic_batch(0, 0, 4, 32, cfg.vocab)
        with mesh:
            params, opt, m = jax.jit(step)(params, opt, b)
        assert np.isfinite(float(m["loss"]))
