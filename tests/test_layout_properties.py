"""Property tests over the layout producers (hypothesis, or the
deterministic example-sweep fallback on a clean container).

For randomized (shape, block, density, n_bins, reorder, value_dtype,
n_shards) draws:

  * ``pack_csc``/``pack_csc_reordered``/``pattern_lower`` round-trip
    through ``to_dense`` BIT-exactly (float layouts; quantized layouts
    keep the exact mask support), and every fresh layout passes
    ``core.validate`` — whatever the knobs.
  * any single mutated leaf fails validation with the MATCHING
    ``LayoutError`` subclass — the taxonomy the artifact loader keys its
    refusal messages on.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean container: deterministic example sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core import validate as V
from repro.kernels import ops


def _block_layout(kn, block, density, n_bins, reorder, value_dtype,
                  n_shards, seed):
    K, N = kn
    bk, bn = block
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((K, N)).astype(np.float32)
    mask = np.kron(rng.random((K // bk, N // bn)) < density,
                   np.ones((bk, bn), bool))
    Nb = N // bn
    if n_shards and Nb % n_shards:
        n_shards = 2 if Nb % 2 == 0 else 0
    pk = ops.pack(w, mask, block, reorder=reorder, n_bins=n_bins,
                  value_dtype=value_dtype, n_shards=n_shards,
                  use_cache=False)
    return pk, w * mask


def _tap_layout(density, n_bins, n_shards, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((16, 8, 3, 3)).astype(np.float32)
    mask = np.asarray(R.pattern_mask(w, connectivity_rate=density))
    tap = ops.pack_taps(w * mask, mask, n_bins=n_bins, n_shards=n_shards,
                        use_cache=False)
    return tap, BCS.conv_lower(w * mask) * BCS.conv_lower(mask)


@settings(max_examples=24, deadline=None)
@given(
    kn=st.sampled_from([(32, 64), (64, 64), (48, 96), (64, 128)]),
    block=st.sampled_from([(8, 8), (16, 16), (8, 16)]),
    density=st.floats(0.1, 0.9),
    n_bins=st.integers(1, 6),
    reorder=st.booleans(),
    value_dtype=st.sampled_from([None, "int8"]),
    n_shards=st.sampled_from([0, 2, 4]),
)
def test_pack_roundtrip_and_validate(kn, block, density, n_bins, reorder,
                                     value_dtype, n_shards):
    """Whatever the knobs, the packed layout validates clean and
    ``to_dense`` reproduces the masked dense weight — bit-exactly for
    float values; quantized layouts keep the exact mask support (zero
    off-mask, nonzero wherever quantization kept a representable value).
    """
    seed = (kn[0] * 31 + kn[1] + block[0] * 7 + block[1]
            + int(density * 1000) + n_bins * 13 + reorder * 17
            + (value_dtype is not None) * 19 + n_shards * 23) % (2 ** 31)
    pk, dense = _block_layout(kn, block, density, n_bins, reorder,
                              value_dtype, n_shards, seed)
    V.validate_layout(pk, path="prop")
    got = np.asarray(pk.to_dense())
    if value_dtype is None:
        np.testing.assert_array_equal(got, dense)
    else:
        assert got.shape == dense.shape
        np.testing.assert_array_equal(got[dense == 0], 0.0)


@settings(max_examples=12, deadline=None)
@given(
    density=st.floats(0.1, 0.8),
    n_bins=st.integers(1, 8),
    n_shards=st.sampled_from([0, 2, 4]),
)
def test_pattern_lower_roundtrip_and_validate(density, n_bins, n_shards):
    """pattern_lower round-trips bit-exactly through the tap layout and
    validates, sharded or not."""
    seed = (int(density * 1000) + n_bins * 13 + n_shards * 23) % (2 ** 31)
    tap, dense = _tap_layout(density, n_bins, n_shards, seed)
    V.validate_layout(tap, path="prop")
    np.testing.assert_array_equal(np.asarray(tap.to_dense()), dense)


# -- single-leaf mutations fail with the matching subclass -------------------

def _replace_bin(layout, field, b, new):
    old = getattr(layout, field)
    return dataclasses.replace(
        layout, **{field: old[:b] + (new,) + old[b + 1:]})


# (name, mutator, expected LayoutError subclass) for PackedLayout
PACKED_MUTATIONS = [
    ("k_idx_out_of_range",
     lambda l: _replace_bin(l, "k_idx", 0,
                            jnp.full_like(l.k_idx[0], l.Kb)),
     V.LayoutIndexError),
    ("k_idx_float_dtype",
     lambda l: _replace_bin(l, "k_idx", 0,
                            l.k_idx[0].astype(jnp.float32)),
     V.LayoutStructureError),
    ("values_wrong_block",
     lambda l: _replace_bin(l, "values", 0, l.values[0][..., :-1]),
     V.LayoutStructureError),
    ("values_dropped_column",
     lambda l: _replace_bin(l, "values", 0,
                            l.values[0][..., 1:, :, :, :]
                            if l.n_shards else l.values[0][1:]),
     V.LayoutStructureError),
    ("nnz_wrong_length",
     lambda l: dataclasses.replace(
         l, nnz=jnp.concatenate([l.nnz, l.nnz], axis=-1)),
     V.LayoutStructureError),
    ("nnz_over_degree",
     lambda l: dataclasses.replace(l, nnz=l.nnz + l.Kb),
     V.LayoutCountError),
    ("perm_duplicate",
     lambda l: dataclasses.replace(
         l, perm=jnp.asarray(np.where(
             np.arange(l.perm.size).reshape(l.perm.shape) == 0,
             np.asarray(l.perm).reshape(-1)[-1],
             np.asarray(l.perm)))),
     V.LayoutPermutationError),
    ("inv_perm_mismatch",
     lambda l: dataclasses.replace(
         l, inv_perm=jnp.roll(l.inv_perm, 1, axis=-1)),
     V.LayoutPermutationError),
    ("shape_not_divisible",
     lambda l: dataclasses.replace(l, shape=(l.shape[0] - 1, l.shape[1])),
     V.LayoutGeometryError),
]

TAP_MUTATIONS = [
    ("t_idx_out_of_range",
     lambda l: _replace_bin(l, "t_idx", 0,
                            jnp.full_like(l.t_idx[0], l.n_alive)),
     V.LayoutIndexError),
    ("alive_not_increasing",
     lambda l: dataclasses.replace(l, alive=l.alive[::-1]),
     V.LayoutIndexError),
    ("k_full_disagrees",
     lambda l: _replace_bin(l, "k_full", 0, l.k_full[0] * 0),
     V.LayoutAuxError),
    ("values_wrong_group",
     lambda l: _replace_bin(
         l, "values", 0,
         jnp.concatenate([l.values[0], l.values[0]], axis=-1)),
     V.LayoutStructureError),
    ("nnz_over_band",
     lambda l: dataclasses.replace(l, nnz=l.nnz + l.n_alive),
     V.LayoutCountError),
]


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
@pytest.mark.parametrize("name,mutate,err",
                         PACKED_MUTATIONS,
                         ids=[m[0] for m in PACKED_MUTATIONS])
def test_packed_mutation_rejected(name, mutate, err, sharded):
    pk, _ = _block_layout((64, 128), (8, 8), 0.5, 3, True, None,
                          2 if sharded else 0, seed=21)
    V.validate_layout(pk)                     # clean before mutation
    with pytest.raises(err):
        V.validate_layout(mutate(pk), path="mut")


@pytest.mark.parametrize("sharded", [False, True],
                         ids=["unsharded", "sharded"])
@pytest.mark.parametrize("name,mutate,err",
                         TAP_MUTATIONS,
                         ids=[m[0] for m in TAP_MUTATIONS])
def test_tap_mutation_rejected(name, mutate, err, sharded):
    tap, _ = _tap_layout(0.5, 3, 2 if sharded else 0, seed=22)
    V.validate_layout(tap)
    with pytest.raises(err):
        V.validate_layout(mutate(tap), path="mut")


def test_quant_scale_mutation_rejected():
    pk, _ = _block_layout((64, 128), (8, 8), 0.5, 3, True, "int8", 0,
                          seed=23)
    V.validate_layout(pk)
    bad = _replace_bin(pk, "scales", 0, pk.scales[0][..., :1, :])
    with pytest.raises(V.LayoutQuantError):
        V.validate_layout(bad, path="mut")
