"""Tensor-parallel PackedLayout/TapLayout sharding tests.

Runs the REAL sharded path on CPU — conftest fakes 8 host devices via
``--xla_force_host_platform_device_count`` — and locks down:

  * tp=1/2/4 parity vs the single-device oracle on every packed producer
    (linear fp32/int8, MoE expert stacks, materialized conv, pattern
    conv).  Sharding never touches per-column accumulation order, so the
    asserts are BIT-identity, not tolerance.
  * degree-balanced shard assignment: max/mean executed-L on skewed
    fixtures stays within the modeled LPT bound (and the BENCH_shard
    gate's 1.15).
  * NamedSharding placement of registered pytree leaves on a real
    multi-device mesh, under jit.
  * artifact round-trip of sharded layouts through the AOT store.
  * ``core.validate`` rejecting every cross-shard invariant violation
    with the matching LayoutError subclass.
  * ServingEngine greedy decode on a tp=2 local mesh == N independent
    ``generate`` calls, and the batched step still traces exactly once.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import bcs as BCS
from repro.core import reweighted as RW
from repro.core import validate as V
from repro.distributed import sharding as SH
from repro.kernels import ops
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import SPARSE_SPEC
from repro.models import transformer as T
from repro.serve import engine as E
from repro.serve.compile import CompileSpec, compile_model
from repro.serve.engine import ServingEngine, generate
from repro.train.trainer import apply_masks

SHARDS = (1, 2, 4)


def _rng(seed=0):
    return np.random.default_rng(seed)


def _block_fixture(seed=0, K=64, N=128, bk=8, bn=8, keep=0.5):
    rng = _rng(seed)
    w = rng.standard_normal((K, N)).astype(np.float32)
    mask = np.kron(rng.random((K // bk, N // bn)) < keep,
                   np.ones((bk, bn), bool))
    return w, mask, (bk, bn)


def _skewed_block_fixture(seed=0, K=128, N=256, bk=8, bn=8):
    """Column-block degrees drawn heavily skewed: a few dense columns, a
    long sparse tail — the worst case for contiguous shard assignment."""
    rng = _rng(seed)
    Kb, Nb = K // bk, N // bn
    mb = np.zeros((Kb, Nb), bool)
    for j in range(Nb):
        deg = Kb if j % 8 == 0 else 1 + int(rng.integers(0, 3))
        mb[rng.permutation(Kb)[:deg], j] = True
    w = rng.standard_normal((K, N)).astype(np.float32)
    return w, np.kron(mb, np.ones((bk, bn), bool)), (bk, bn)


def _conv_fixture(seed=0, P=16, Q=8, k=3):
    rng = _rng(seed)
    w = rng.standard_normal((P, Q, k, k)).astype(np.float32)
    mask = rng.random((P, Q, k, k)) < 0.4
    mask[0] = True
    return w, mask


def _lm(arch, **over):
    cfg = configs.get(arch, smoke=True)
    if over:
        cfg = cfg.replace(**over)
    return T.init_lm(jax.random.PRNGKey(0), cfg), cfg


# -- parity vs the single-device oracle, every packed producer ---------------

class TestShardedParity:
    @pytest.mark.parametrize("S", SHARDS)
    def test_linear_bit_identical(self, S):
        """Sharded sparse_linear == unsharded oracle, bitwise: per-column
        accumulation order is untouched by the shard split."""
        w, mask, block = _block_fixture()
        x = jnp.asarray(_rng(1).standard_normal((4, w.shape[0])),
                        jnp.float32)
        bias = jnp.asarray(_rng(2).standard_normal(w.shape[1]), jnp.float32)
        ref = ops.sparse_linear(
            x, packed=ops.pack(w, mask, block, reorder=True),
            bias=bias, act="silu")
        got = ops.sparse_linear(
            x, packed=ops.pack(w, mask, block, n_shards=S),
            bias=bias, act="silu")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("S", (2, 4))
    def test_linear_int8_bit_identical(self, S):
        """The quantized value path shards too: int8 values + fp32 scale
        leaves carry the shard axis, outputs stay bit-identical."""
        w, mask, block = _block_fixture(seed=3)
        x = jnp.asarray(_rng(4).standard_normal((3, w.shape[0])),
                        jnp.float32)
        ref = ops.sparse_linear(
            x, packed=ops.pack(w, mask, block, reorder=True,
                               value_dtype="int8"))
        got = ops.sparse_linear(
            x, packed=ops.pack(w, mask, block, n_shards=S,
                               value_dtype="int8"))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("S", (2, 4))
    def test_conv_bit_identical(self, S):
        """Materialized sparse conv (im2col GEMM) over a sharded layout;
        sharded layouts never take the implicit kernel."""
        w, mask = _conv_fixture()
        wl = BCS.conv_lower(w)
        ml = BCS.conv_lower(mask)
        gemm_block, _ = BCS.conv_gemm_block((4, 4), w.shape)
        x = jnp.asarray(_rng(5).standard_normal((2, 10, 10, w.shape[1])),
                        jnp.float32)
        kh, kw = w.shape[2], w.shape[3]
        conv = (kh, kw, w.shape[1])
        ref = ops.sparse_conv2d(
            x, ops.pack(wl, ml, gemm_block, reorder=True, conv=conv),
            kh=kh, kw=kw, implicit=False)
        got = ops.sparse_conv2d(
            x, ops.pack(wl, ml, gemm_block, n_shards=S, conv=conv),
            kh=kh, kw=kw)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("S", (2, 4))
    def test_pattern_conv_bit_identical(self, S):
        """Pattern (tap-gather) conv over a sharded TapLayout."""
        w, mask = _conv_fixture(seed=6)
        x = jnp.asarray(_rng(7).standard_normal((2, 9, 9, w.shape[1])),
                        jnp.float32)
        kh, kw = w.shape[2], w.shape[3]
        ref = ops.sparse_conv2d_pattern(x, ops.pack_taps(w, mask),
                                        kh=kh, kw=kw)
        got = ops.sparse_conv2d_pattern(
            x, ops.pack_taps(w, mask, n_shards=S), kh=kh, kw=kw)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("S", (2, 4))
    def test_moe_expert_stack_sharded_free(self, S):
        """MoE expert layouts shard along the leading expert axis (never
        block columns): placing them with expert_layout_specs on a real
        mesh leaves sparse_expert_linear bit-identical under jit."""
        rng = _rng(8)
        E_, din, dout, bk = 4, 32, 48, 8
        w = rng.standard_normal((E_, din, dout)).astype(np.float32)
        mb = rng.random((E_, din // bk, dout // bk)) < 0.5
        mask = np.kron(mb, np.ones((bk, bk), bool))
        from repro.serve.compile import _pack_stacked
        packed, _ = _pack_stacked(w, mask, (bk, bk))
        assert packed.n_shards == 0
        x = jnp.asarray(rng.standard_normal((E_, 5, din)), jnp.float32)
        ref = ops.sparse_expert_linear(x, packed)
        mesh = make_local_mesh(tp=S)
        shardings = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            SH.expert_layout_specs(packed),
            is_leaf=lambda p: isinstance(p, jax.sharding.PartitionSpec))
        placed = jax.device_put(packed, shardings)
        got = jax.jit(lambda xx: ops.sparse_expert_linear(xx, placed))(x)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("S", SHARDS)
    def test_to_dense_roundtrip(self, S):
        """Sharded layouts still reconstruct the masked dense weight
        exactly — shard-major storage + global perm lose nothing."""
        w, mask, block = _block_fixture(seed=9)
        pl = ops.pack(w, mask, block, n_shards=S)
        np.testing.assert_array_equal(np.asarray(pl.to_dense()), w * mask)
        wc, mc = _conv_fixture(seed=10)
        tl = ops.pack_taps(wc, mc, n_shards=S)
        np.testing.assert_array_equal(
            np.asarray(tl.to_dense()),
            BCS.conv_lower(wc) * BCS.conv_lower(mc))

    def test_column_sharding_never_reaches_expert_kernel(self):
        w, mask, block = _block_fixture()
        pk = ops.pack(w, mask, block, n_shards=2)
        x = jnp.zeros((2, 3, w.shape[0]))
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.stack([a, a]), pk)
        with pytest.raises(AssertionError, match="expert"):
            ops.sparse_expert_linear(x, stacked)


# -- degree-balanced shard assignment ----------------------------------------

class TestShardBalance:
    @pytest.mark.parametrize("S", (2, 4))
    def test_skewed_fixture_within_gate(self, S):
        """On the skewed fixture the LPT assignment keeps the straggler
        factor (max/mean executed blocks per independently-padded shard)
        within the BENCH_shard gate."""
        w, mask, block = _skewed_block_fixture()
        pl = ops.pack(w, mask, block, n_shards=S)
        assert pl.shard_balance <= 1.15, pl.shard_balance

    @pytest.mark.parametrize("S", (2, 4))
    def test_lpt_load_bound(self, S):
        """Raw per-shard nnz load obeys the LPT bound: max load <= mean
        load + the heaviest single column (greedy puts each column on the
        lightest open shard)."""
        w, mask, block = _skewed_block_fixture(seed=11)
        bk, bn = block
        mb = mask[::bk, ::bn]
        cnt = mb.sum(axis=0).astype(np.int64)
        assign = BCS.shard_columns(cnt, S)
        loads = cnt[assign].sum(axis=1)
        assert loads.max() <= loads.mean() + cnt.max()

    @pytest.mark.parametrize("S", (2, 4))
    def test_beats_contiguous_assignment(self, S):
        """Degree-balanced assignment is never worse than naive contiguous
        column chunks on the skewed fixture."""
        w, mask, block = _skewed_block_fixture(seed=12)
        pl = ops.pack(w, mask, block, n_shards=S)
        bk, bn = block
        cnt = mask[::bk, ::bn].sum(axis=0)
        Nb = cnt.shape[0]
        naive = cnt.reshape(S, Nb // S).sum(axis=1)
        naive_ratio = naive.max() / naive.mean()
        assert pl.shard_balance <= naive_ratio + 1e-9

    def test_shard_columns_rejects_bad_counts(self):
        with pytest.raises(ValueError, match="divide"):
            BCS.shard_columns(np.ones(10, np.int64), 3)
        with pytest.raises(ValueError, match=">= 1"):
            BCS.shard_columns(np.ones(10, np.int64), 0)

    def test_equal_shard_widths(self):
        """Capacity-exact LPT: every shard owns exactly Nb/S columns (the
        stacking + NamedSharding invariant)."""
        w, mask, block = _skewed_block_fixture(seed=13)
        for S in (2, 4):
            pl = ops.pack(w, mask, block, n_shards=S)
            assert np.asarray(pl.perm).shape == (S, pl.Nb // S)
            flat = np.sort(np.asarray(pl.perm).reshape(-1))
            np.testing.assert_array_equal(flat, np.arange(pl.Nb))


# -- mesh + NamedSharding placement ------------------------------------------

class TestMeshPlacement:
    def test_make_local_mesh_tp(self):
        mesh = make_local_mesh(tp=4)
        assert mesh.shape == {"data": 1, "model": 4}
        assert make_local_mesh().shape == {"data": 1, "model": 1}
        with pytest.raises(ValueError, match=">= 1"):
            make_local_mesh(tp=0)
        with pytest.raises(ValueError, match="devices"):
            make_local_mesh(tp=jax.device_count() + 1)

    @pytest.mark.parametrize("S", (2, 4))
    def test_placement_under_jit_bit_identical(self, S):
        """device_put with layout_shardings really splits the shard axis
        across S devices; jitted sparse_linear on the placed layout stays
        bit-identical to the single-device oracle."""
        w, mask, block = _block_fixture(seed=14)
        ref = ops.sparse_linear(
            jnp.eye(w.shape[0]), packed=ops.pack(w, mask, block,
                                                 reorder=True))
        pk = ops.pack(w, mask, block, n_shards=S)
        mesh = make_local_mesh(tp=S)
        placed = jax.device_put(pk, SH.layout_shardings(pk, mesh))
        assert len(placed.values[0].sharding.device_set) == S
        assert placed.inv_perm.sharding.is_fully_replicated
        got = jax.jit(
            lambda x: ops.sparse_linear(x, packed=placed))(
                jnp.eye(w.shape[0]))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_partition_specs_shapes(self):
        """The spec tree maps exactly the shard stack dim to "model"."""
        w, mask, block = _block_fixture(seed=15)
        pk = ops.pack(w, mask, block, n_shards=2)
        specs = SH.layout_partition_specs(pk)
        P = jax.sharding.PartitionSpec
        assert specs.values[0] == P("model", None, None, None, None)
        assert specs.k_idx[0] == P("model", None, None)
        assert specs.nnz == P("model", None)
        assert specs.perm == P("model", None)
        assert specs.inv_perm == P()
        unsh = ops.pack(w, mask, block, reorder=True)
        for s in jax.tree_util.tree_leaves(
                SH.layout_partition_specs(unsh),
                is_leaf=lambda x: isinstance(x, P)):
            assert s == P()

    def test_shard_packed_tree_walks_params(self):
        w, mask, block = _block_fixture(seed=16)
        tree = {"blk": {"ffn": {"gate": {
            "w": jnp.asarray(w),
            "packed": ops.pack(w, mask, block, n_shards=2)}}}}
        mesh = make_local_mesh(tp=2)
        out = SH.shard_packed_tree(tree, mesh)
        pk = out["blk"]["ffn"]["gate"]["packed"]
        assert len(pk.values[0].sharding.device_set) == 2
        # non-layout leaves untouched
        assert out["blk"]["ffn"]["gate"]["w"] is tree["blk"]["ffn"]["gate"]["w"]


# -- artifact round-trip ------------------------------------------------------

class TestShardedArtifacts:
    def test_roundtrip_preserves_shards(self, tmp_path):
        """Sharded layouts survive the AOT store: the warm start carries
        n_shards and decodes bit-identically, with zero repacking."""
        spec_map = [(r"ffn/(gate|up)/w", RW.SchemeChoice("block", (16, 16)))]
        params = {"blk": {"ffn": {
            "gate": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                            (64, 96), jnp.float32)},
            "up": {"w": jax.random.normal(jax.random.PRNGKey(1),
                                          (64, 96), jnp.float32)}}}}
        masks = RW.random_block_masks(params, spec_map, (16, 16),
                                      keep_prob=0.4)
        pm = apply_masks(params, masks)
        cs = CompileSpec(tp=2)
        e1, r1 = compile_model(pm, masks, spec_map, spec=cs,
                               artifact_dir=tmp_path)
        ops.clear_pack_cache()
        misses = ops.pack_cache_stats()["misses"]
        e2, r2 = compile_model(pm, masks, spec_map, spec=cs,
                               artifact_dir=tmp_path)
        assert ops.pack_cache_stats()["misses"] == misses
        pk1 = e1["blk"]["ffn"]["gate"]["packed"]
        pk2 = e2["blk"]["ffn"]["gate"]["packed"]
        assert pk1.n_shards == pk2.n_shards == 2
        V.validate_tree(e2)
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
        np.testing.assert_array_equal(
            np.asarray(ops.sparse_linear(x, packed=pk1)),
            np.asarray(ops.sparse_linear(x, packed=pk2)))

    def test_tp_in_model_digest(self):
        """CompileSpec.tp is digest-covered: a tp=1 artifact never warm
        starts a tp=2 compile."""
        assert CompileSpec(tp=1).digest_fields() != \
            CompileSpec(tp=2).digest_fields()
        assert CompileSpec(tp=2) == CompileSpec(tp=2)


# -- cross-shard invariant rejection -----------------------------------------

class TestValidateSharded:
    @pytest.fixture()
    def packed(self):
        w, mask, block = _block_fixture(seed=17)
        return ops.pack(w, mask, block, n_shards=2, use_cache=False)

    @pytest.fixture()
    def tap(self):
        w, mask = _conv_fixture(seed=18)
        return ops.pack_taps(w, mask, n_shards=2, use_cache=False)

    def _expect(self, layout, err, **repl):
        with pytest.raises(err):
            V.validate_layout(dataclasses.replace(layout, **repl))

    def test_sharded_layouts_validate(self, packed, tap):
        V.validate_layout(packed)
        V.validate_layout(tap)

    def test_nondividing_shard_count(self, packed, tap):
        self._expect(packed, V.LayoutGeometryError, n_shards=3)
        self._expect(tap, V.LayoutGeometryError, n_shards=7)

    def test_missing_shard_axis_on_values(self, packed, tap):
        self._expect(packed, V.LayoutStructureError,
                     values=tuple(v[0] for v in packed.values))
        self._expect(tap, V.LayoutStructureError,
                     values=tuple(v[0] for v in tap.values))

    def test_nnz_without_shard_axes(self, packed, tap):
        self._expect(packed, V.LayoutStructureError,
                     nnz=packed.nnz.reshape(-1))
        self._expect(tap, V.LayoutStructureError, nnz=tap.nnz.reshape(-1))

    def test_sharded_requires_perm(self, packed, tap):
        self._expect(packed, V.LayoutPermutationError,
                     perm=None, inv_perm=None)
        self._expect(tap, V.LayoutPermutationError, perm=None,
                     inv_perm=None)

    def test_flat_perm_rejected(self, packed):
        self._expect(packed, V.LayoutStructureError,
                     perm=packed.perm.reshape(-1))

    def test_cross_shard_duplicate_column(self, packed, tap):
        """One shard claiming another's column — the corruption that would
        silently scramble merge_shards — is a permutation violation."""
        for layout in (packed, tap):
            p = np.asarray(layout.perm).copy()
            p[0, 0] = p[1, 0]
            self._expect(layout, V.LayoutPermutationError,
                         perm=jnp.asarray(p))

    def test_inconsistent_inv_perm(self, packed):
        ip = np.asarray(packed.inv_perm).copy()
        ip[0], ip[1] = ip[1], ip[0]
        self._expect(packed, V.LayoutPermutationError,
                     inv_perm=jnp.asarray(ip))

    def test_wrong_shard_count_aux(self, packed):
        """Aux shard count disagreeing with the actual leaf shard axis."""
        self._expect(packed, V.LayoutError, n_shards=4)

    def test_validate_tree_finds_sharded_layouts(self, packed):
        tree = {"a": {"packed": packed},
                "b": {"packed": dataclasses.replace(
                    packed, nnz=packed.nnz.reshape(-1))}}
        with pytest.raises(V.LayoutStructureError, match="b"):
            V.validate_tree(tree)
        assert V.validate_tree({"a": {"packed": packed}}) == 1


# -- serving on a tp=2 local mesh --------------------------------------------

def _compiled_tp2(family):
    arch = {"dense": "yi-9b", "moe": "mixtral-8x7b",
            "hybrid": "hymba-1.5b"}[family]
    params, cfg = _lm(arch)
    masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None, rate=0.6)
    params = apply_masks(params, masks)
    params, rep = compile_model(params, masks, SPARSE_SPEC,
                                spec=CompileSpec(keep_dense=False, tp=2))
    assert any(r.get("shards") == 2 for r in rep.packed)
    # MoE expert stacks must stay column-unsharded (expert axis shards)
    for r in rep.packed:
        if "moe" in r["path"].split("/"):
            assert r.get("shards") is None
    mesh = make_local_mesh(tp=2)
    dist = SH.make_dist(mesh, cfg, 2)
    return SH.shard_packed_tree(params, mesh), cfg, dist


class TestEngineTensorParallel:
    @pytest.mark.parametrize("family", ["dense", "moe", "hybrid"])
    def test_engine_matches_generate_tp2(self, family):
        """Greedy engine decode with sharded packed params on the tp=2
        mesh == N independent generate calls (same dist)."""
        params, cfg, dist = _compiled_tp2(family)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(1, cfg.vocab, size=n).tolist()
                   for n in (8, 5)]
        eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32, dist=dist)
        rids = [eng.submit(p, 4) for p in prompts]
        eng.run()
        for rid, p in zip(rids, prompts):
            want = np.asarray(
                generate(params, cfg, jnp.asarray([p], jnp.int32), 4,
                         dist=dist))[0].tolist()
            assert eng.requests[rid].status == "finished"
            assert eng.requests[rid].tokens == want

    def test_engine_step_traces_once_sharded(self, monkeypatch):
        """Admission/eviction/slot reuse never retrace the SHARDED batched
        decode step."""
        params, cfg, dist = _compiled_tp2("dense")
        traces = []

        def counting(fn):
            def wrapped(*a, **kw):
                traces.append(1)
                return fn(*a, **kw)
            return wrapped

        monkeypatch.setattr(T, "decode_step_ragged",
                            counting(T.decode_step_ragged))
        E._JIT_CACHE.clear()
        eng = ServingEngine(params, cfg, n_slots=2, seq_cap=32, dist=dist)
        rng = np.random.RandomState(1)
        for i, n in enumerate((8, 5, 12)):
            eng.submit(rng.randint(1, cfg.vocab, size=n).tolist(), 4,
                       arrival=i)
        eng.run()
        assert eng.stats["finished"] == 3
        assert len(traces) == 1
