"""Pallas BCS block-sparse matmul vs the pure-jnp oracle (interpret mode):
shape/dtype sweeps + zero-block skipping + epilogue fusion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean container: deterministic example sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.kernels import ref
from repro.kernels.bsr_matmul import bsr_matmul
from repro.kernels import ops


def make_case(M, K, N, bk, bn, zero_frac, seed=0, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (K, N), jnp.float32)
    # kill whole blocks explicitly (the skip path under test)
    Kb, Nb = K // bk, N // bn
    keep = jax.random.uniform(k2, (Kb, Nb)) > zero_frac
    mask = jnp.repeat(jnp.repeat(keep, bk, 0), bn, 1).astype(jnp.float32)
    b = BCS.from_dense(np.asarray(w), np.asarray(mask), (bk, bn))
    vals, kidx, nnz = BCS.pad_to_uniform_csc(b)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K), jnp.float32)
    return (x.astype(dtype), vals.astype(dtype), kidx,
            w.astype(dtype), mask)


SHAPES = [(64, 128, 128, 64, 64), (128, 256, 384, 64, 128),
          (256, 128, 256, 128, 128), (32, 512, 128, 128, 128)]


@pytest.mark.parametrize("M,K,N,bk,bn", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(M, K, N, bk, bn, dtype):
    x, vals, kidx, w, mask = make_case(M, K, N, bk, bn, zero_frac=0.4,
                                       dtype=dtype)
    y_k = bsr_matmul(x, vals, kidx, bm=min(64, M), interpret=True)
    y_r = ref.bsr_matmul_ref(x, vals, kidx)
    y_m = ref.masked_matmul_ref(x, w, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y_r, np.float32),
                               np.asarray(y_m, np.float32),
                               rtol=tol, atol=tol)


def test_all_blocks_zero_column(self=None):
    """A fully-pruned block column must produce exactly zero output."""
    x, vals, kidx, w, mask = make_case(64, 128, 256, 64, 64, zero_frac=0.0)
    mask = mask.at[:, :64].set(0.0)
    b = BCS.from_dense(np.asarray(w), np.asarray(mask), (64, 64))
    vals, kidx, nnz = BCS.pad_to_uniform_csc(b)
    y = bsr_matmul(x, vals, kidx, bm=64, interpret=True)
    assert jnp.allclose(y[:, :64], 0.0)


@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_epilogue_fusion(act):
    x, vals, kidx, w, mask = make_case(64, 128, 128, 64, 64, zero_frac=0.3)
    bias = jax.random.normal(jax.random.PRNGKey(9), (128,))
    y_k = bsr_matmul(x, vals, kidx, bias=bias, bm=64, act=act,
                     interpret=True)
    y_r = ref.bsr_matmul_ref(x, vals, kidx, bias=bias, act=act)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(mi=st.sampled_from([1, 2, 4]), ki=st.sampled_from([2, 3]),
       ni=st.sampled_from([2, 3]), zf=st.floats(0.0, 0.8),
       seed=st.integers(0, 20))
def test_kernel_property_sweep(mi, ki, ni, zf, seed):
    """Property: kernel == oracle for random grids/sparsities."""
    bk = bn = 64
    M, K, N = 64 * mi, bk * ki, bn * ni
    x, vals, kidx, w, mask = make_case(M, K, N, bk, bn, zf, seed)
    y_k = bsr_matmul(x, vals, kidx, bm=64, interpret=True)
    y_m = ref.masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-3, atol=1e-3)


def test_ops_dispatch_dense_fallback():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 3, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y = ops.sparse_linear(x, w=w)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.einsum("bsi,io->bso", x, w)),
                               rtol=1e-4, atol=1e-4)


def test_ops_pack_and_run():
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    m = R.make_mask(w, "block_row", block=(64, 64), rate=0.5)
    packed = ops.pack(w, m, (64, 64))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    y = ops.sparse_linear(x, packed=packed, bm=64)
    y_ref = ref.masked_matmul_ref(x, w, m)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_pallas_interpret_env_override(monkeypatch):
    """PALLAS_INTERPRET pins the kernel execution mode in both directions
    (the TPU CI hook); unset falls back to backend auto-detection."""
    from repro.kernels import bsr_matmul as BM

    monkeypatch.setenv("PALLAS_INTERPRET", "1")
    assert BM._auto_interpret() is True
    monkeypatch.setenv("PALLAS_INTERPRET", "false")
    assert BM._auto_interpret() is False
    monkeypatch.setenv("PALLAS_INTERPRET", "")
    assert BM._auto_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.delenv("PALLAS_INTERPRET")
    assert BM._auto_interpret() == (jax.default_backend() != "tpu")
