"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions; decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T
from repro.serve.engine import prefill, generate
from repro.train.trainer import make_train_step

ARCHS = list(configs.ALIASES.keys())


def batch_for(cfg, B=2, S=32, step=0):
    return synthetic_batch(
        0, step, B, S, cfg.vocab,
        frontend_tokens=cfg.n_frontend_tokens
        if cfg.family in ("encdec", "vlm") else 0, d_model=cfg.d_model)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get(arch, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = batch_for(cfg)
    logits, aux = T.forward(params, cfg, b["tokens"],
                            frontend=b.get("frontend"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, step_fn = make_train_step(cfg, lr=1e-3)
    opt = opt_init(params)
    b = batch_for(cfg)
    params2, opt2, m = jax.jit(step_fn)(params, opt, b)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    d = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b_.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "mamba2-1.3b",
                                  "hymba-1.5b", "seamless-m4t-large-v2",
                                  "llama-3.2-vision-90b"])
def test_smoke_generate(arch):
    cfg = configs.get(arch, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = batch_for(cfg)
    out = generate(params, cfg, b["tokens"], 4, frontend=b.get("frontend"))
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab


def test_decode_matches_forward_dense():
    """Teacher-forced decode over the cache == full forward (dense arch)."""
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = batch_for(cfg, B=2, S=16)
    toks = b["tokens"]
    logits_full, _ = T.forward(params, cfg, toks)
    _, cache = prefill(params, cfg, toks[:, :8])
    # pad ring cache to full seq capacity for positions 8..15
    cache_big = T.init_cache(params, cfg, 2, 16)
    kv = cache["kv"]
    cache_big["kv"]["k"] = cache_big["kv"]["k"].at[:, :, :8].set(kv["k"])
    cache_big["kv"]["v"] = cache_big["kv"]["v"].at[:, :, :8].set(kv["v"])
    cache_big["kv"]["pos"] = cache_big["kv"]["pos"].at[:, :8].set(kv["pos"])
    # invalidate unwritten slots so they can't be attended to
    cache_big["kv"]["pos"] = cache_big["kv"]["pos"].at[:, 8:].set(1 << 28)
    c = cache_big
    for i in range(8, 12):
        pos = jnp.full((2, 1), i, jnp.int32)
        lg, c = T.decode_step(params, cfg, toks[:, i:i + 1], c, pos)
        ref = logits_full[:, i, :]
        got = lg[:, 0, :]
        top_ref = jnp.argmax(ref, -1)
        top_got = jnp.argmax(got, -1)
        np.testing.assert_array_equal(np.asarray(top_ref),
                                      np.asarray(top_got))


def test_ssm_decode_matches_forward():
    """SSD chunked scan and the O(1) recurrent step agree (mamba2)."""
    cfg = configs.get("mamba2-1.3b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = batch_for(cfg, B=2, S=16)
    toks = b["tokens"]
    logits_full, _ = T.forward(params, cfg, toks)
    _, cache = prefill(params, cfg, toks[:, :15])
    pos = jnp.full((2, 1), 15, jnp.int32)
    lg, _ = T.decode_step(params, cfg, toks[:, 15:16], cache, pos)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0, :], np.float32),
        np.asarray(logits_full[:, 15, :], np.float32), rtol=0.15, atol=0.15)


def test_loss_decreases_on_bigram_task():
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(42), cfg)
    opt_init, step_fn = make_train_step(cfg, lr=5e-3)
    opt = opt_init(params)
    step_fn = jax.jit(step_fn)
    losses = []
    for i in range(60):
        b = batch_for(cfg, B=8, S=32, step=i)
        params, opt, m = step_fn(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts(arch):
    """Full (non-smoke) configs instantiate ABSTRACTLY with the right
    scale — no allocation (eval_shape)."""
    cfg = configs.get(arch)
    abs_params = jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    n = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(abs_params))
    expected = {"yi-9b": 9e9, "granite-8b": 8e9, "minitron-8b": 8e9,
                "phi3-medium-14b": 14e9, "mamba2-1.3b": 1.3e9,
                "mixtral-8x7b": 47e9, "kimi-k2-1t-a32b": 1.0e12,
                "hymba-1.5b": 1.5e9, "seamless-m4t-large-v2": 2.3e9,
                "llama-3.2-vision-90b": 90e9}[arch]
    assert 0.5 * expected < n < 1.7 * expected, f"{arch}: {n:.3g}"
