"""End-to-end sparse execution path tests: vectorized packing bit-identity,
kernel M-padding, bf16 accumulation, effective FLOP accounting,
compile_model whole-model parity, and the fused decode loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # clean container: deterministic example sweep
    from _hypothesis_fallback import given, settings, st

from repro import configs
from repro.core import bcs as BCS
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.kernels.ref import masked_matmul_ref
from repro.models import module as M
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.compile import CompileSpec, compile_model
from repro.serve.engine import generate, generate_python
from repro.train.trainer import apply_masks
from repro.data.pipeline import synthetic_batch


def block_case(K, N, bk, bn, zero_frac, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = np.asarray(jax.random.normal(k1, (K, N), jnp.float32))
    keep = np.asarray(jax.random.uniform(k2, (K // bk, N // bn))) > zero_frac
    mask = np.repeat(np.repeat(keep, bk, 0), bn, 1).astype(np.float32)
    return w, mask


# -- vectorized packing == loop packer, bit for bit --------------------------

@settings(max_examples=12, deadline=None)
@given(bk=st.sampled_from([4, 16, 32]), bn=st.sampled_from([8, 32, 64]),
       zf=st.floats(0.0, 0.95), seed=st.integers(0, 40))
def test_vectorized_packing_bit_identical(bk, bn, zf, seed):
    w, mask = block_case(128, 256, bk, bn, zf, seed)
    a = BCS.from_dense(w, mask, (bk, bn))
    b = BCS.from_dense_loop(w, mask, (bk, bn))
    assert np.array_equal(a.values, b.values)
    assert np.array_equal(a.col_idx, b.col_idx)
    assert np.array_equal(a.row_ptr, b.row_ptr)
    assert np.array_equal(a.occurrence, b.occurrence)
    assert len(a.patterns) == len(b.patterns)
    for pa, pb in zip(a.patterns, b.patterns):
        assert np.array_equal(pa, pb)
    va, ka, na = BCS.pad_to_uniform_csc(a)
    vb, kb, nb = BCS.pad_to_uniform_csc_loop(b)
    assert np.array_equal(np.asarray(va), np.asarray(vb))
    assert np.array_equal(np.asarray(ka), np.asarray(kb))
    assert np.array_equal(np.asarray(na), np.asarray(nb))


def test_fine_grained_survivors_inside_blocks():
    """Intra-block sparsity rides along: a block with ONE live weight is
    stored (with interior zeros), and the vectorized packer keeps it."""
    w = np.ones((64, 64), np.float32)
    mask = np.zeros((64, 64), np.float32)
    mask[3, 40] = 1.0                       # one weight in block (0, 1)
    b = BCS.from_dense(w, mask, (32, 32))
    assert b.nnzb == 1 and b.col_idx.tolist() == [1]
    np.testing.assert_allclose(BCS.to_dense(b), w * mask)


# -- dispatch: ragged M runs the kernel (no dense fallback) ------------------

@pytest.mark.parametrize("M", [1, 7, 100, 129])
def test_sparse_linear_ragged_m_matches_reference(M):
    w, mask = block_case(128, 128, 32, 32, 0.5, seed=2)
    packed = ops.pack(w, mask, (32, 32))
    x = jax.random.normal(jax.random.PRNGKey(3), (M, 128), jnp.float32)
    y = ops.sparse_linear(x, packed=packed, bm=64)
    y_ref = masked_matmul_ref(x, jnp.asarray(w), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_sparse_linear_bf16_fp32_accumulation():
    """bf16 in / bf16 out with fp32 accumulation: kernel must track the
    fp32-accumulated reference to bf16 rounding, not bf16-accumulation."""
    w, mask = block_case(256, 128, 64, 64, 0.3, seed=4)
    packed = ops.pack(jnp.asarray(w, jnp.bfloat16), mask, (64, 64))
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 256), jnp.bfloat16)
    y = ops.sparse_linear(x, packed=packed)
    y_ref = masked_matmul_ref(x, jnp.asarray(w, jnp.bfloat16),
                              jnp.asarray(mask))
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_pack_cache_hits():
    ops.clear_pack_cache()
    w, mask = block_case(128, 128, 32, 32, 0.5, seed=6)
    p1 = ops.pack(w, mask, (32, 32))
    p2 = ops.pack(w, mask, (32, 32))
    assert p1.values[0] is p2.values[0]     # cached, not repacked
    p3 = ops.pack(w, mask, (32, 32), use_cache=False)
    assert p3.values[0] is not p1.values[0]
    np.testing.assert_array_equal(np.asarray(p3.values[0]),
                                  np.asarray(p1.values[0]))


def test_pack_cache_keys_reorder_and_block_apart():
    """Reordered and unreordered packs of the SAME weights must not collide
    in the content cache — the key carries (block, reorder, n_bins)."""
    ops.clear_pack_cache()
    w, mask = block_case(128, 128, 32, 32, 0.6, seed=7)
    plain = ops.pack(w, mask, (32, 32))
    reord = ops.pack(w, mask, (32, 32), reorder=True, n_bins=2)
    reord4 = ops.pack(w, mask, (32, 32), reorder=True, n_bins=4)
    other_block = ops.pack(w, mask, (16, 16))
    assert plain.perm is None and reord.perm is not None
    assert reord.n_bins != reord4.n_bins or reord is not reord4
    assert other_block.block == (16, 16)
    # hits still work per-variant
    assert ops.pack(w, mask, (32, 32), reorder=True,
                    n_bins=2).values[0] is reord.values[0]
    assert ops.pack(w, mask, (32, 32)).values[0] is plain.values[0]


def test_flops_saved_is_effective_not_raw_density():
    """Imbalanced column degrees: raw block density overstates savings —
    flops_saved must report the uniform-padded L/Kb, not 1 - density."""
    w = np.ones((128, 128), np.float32)
    mask = np.zeros((128, 128), np.float32)
    mask[:, :32] = 1.0                      # column 0: all 4 k-blocks live
    mask[:32, 32:64] = 1.0                  # column 1: 1 live block
    packed = ops.pack(w, mask, (32, 32))
    # density = 5/16 but L = max degree = 4 of Kb = 4 -> nothing skipped
    assert packed.density == pytest.approx(5 / 16)
    assert ops.flops_saved(packed) == 0.0
    assert ops.padding_overhead(packed) == pytest.approx(16 / 5)
    # ... until row reordering bins the heavy column away from the light
    # ones: the same matrix under the binned layout skips most of the pad
    reordered = ops.pack(w, mask, (32, 32), reorder=True, n_bins=4)
    assert reordered.L_effective < reordered.L_max
    assert ops.flops_saved(reordered) > 0.5
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.sparse_linear(x, packed=packed, bm=64)),
        np.asarray(ops.sparse_linear(x, packed=reordered, bm=64)))


# -- row reordering: round-trip + bit-identity through layers.linear ---------

@pytest.mark.parametrize("n_bins", [1, 2, 4])
def test_reorder_roundtrip_bit_identity_layers_linear(n_bins):
    """Reordered layout reconstructs the exact masked weight, and
    ``layers.linear`` produces bit-identical outputs with and without the
    reorder (per-column accumulation order is untouched; the epilogue
    gather only relabels output columns)."""
    w, mask = block_case(128, 192, 16, 16, 0.7, seed=9)
    plain = ops.pack(w, mask, (16, 16))
    reord = ops.pack(w, mask, (16, 16), reorder=True, n_bins=n_bins)
    np.testing.assert_array_equal(reord.to_dense(), w * mask)
    x = jax.random.normal(jax.random.PRNGKey(10), (5, 128), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(11), (192,), jnp.float32)
    y0 = L.linear({"w": jnp.asarray(w), "b": b, "packed": plain}, x,
                  act="silu")
    y1 = L.linear({"w": jnp.asarray(w), "b": b, "packed": reord}, x,
                  act="silu")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    # and the executed degree never exceeds the unreordered padding
    assert reord.L_effective <= plain.L_max


# -- compile_model: whole-model forward == dense-masked reference ------------

def _whole_block_masks(params, spec, block, seed=0):
    """Masks that kill whole (bk, bn) blocks on spec-matched leaves."""
    return RW.random_block_masks(params, spec, block, keep_prob=0.5,
                                 seed=seed)


ATTN_SPEC = [(r"attn/w[qkvo]/w", RW.SchemeChoice("block", (16, 16)))]
FFN_SPEC = [(r"ffn/(gate|up|down)/w", RW.SchemeChoice("block", (16, 16)))]


@pytest.mark.parametrize("case,spec", [
    ("attention", ATTN_SPEC),           # qkv/out projections packed
    ("ffn_heavy", FFN_SPEC),            # gate/up/down packed, wider d_ff
])
def test_compile_model_forward_parity(case, spec):
    """Whole-model packed forward == dense-masked forward, in fp32 (in bf16
    the fused silu epilogue legitimately differs by ~1 ulp — it applies the
    activation before the output rounding; see layers.ffn)."""
    cfg = configs.get("yi-9b", smoke=True)
    if case == "ffn_heavy":
        cfg = cfg.replace(d_ff=256)
    params = M.cast_tree(T.init_lm(jax.random.PRNGKey(0), cfg), jnp.float32)
    masks = _whole_block_masks(params, spec, (16, 16))
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, spec)
    assert any(r["packed"] for r in report), report
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    ld, _ = T.forward(pm, cfg, tokens)
    ls, _ = T.forward(exec_params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(ld, np.float32),
                               np.asarray(ls, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_compile_model_drop_dense_and_generate():
    """keep_dense=False serving: packed layers lose "w" entirely and the
    model still prefills + decodes through the kernel path."""
    cfg = configs.get("yi-9b", smoke=True)
    params = M.cast_tree(T.init_lm(jax.random.PRNGKey(0), cfg), jnp.float32)
    spec = ATTN_SPEC + FFN_SPEC
    masks = _whole_block_masks(params, spec, (16, 16))
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, spec,
                                        spec=CompileSpec(keep_dense=False))
    packed_paths = [r["path"] for r in report if r["packed"]]
    assert packed_paths
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    ref = generate(pm, cfg, tokens, 4)
    out = generate(exec_params, cfg, tokens, 4)
    assert out.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_compile_model_skips_unprunable_and_indivisible():
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    spec = [(r"attn/wq/w", RW.SchemeChoice("block", (48, 48))),   # 48 ∤ 64
            (r"ffn/gate/w", RW.SchemeChoice("none"))]
    masks = _whole_block_masks(params, [(r"attn/wq/w", RW.SchemeChoice())],
                               (16, 16))
    exec_params, report = compile_model(params, masks, spec)
    by_path = {r["path"]: r for r in report}
    assert not by_path["layers/attn/wq/w"]["packed"]
    assert "does not divide" in by_path["layers/attn/wq/w"]["reason"]
    assert not by_path["layers/ffn/gate/w"]["packed"]


# -- MoE: batched sparse expert execution ------------------------------------

MOE_SPEC = [(r"moe/(gate|up|down)/w", RW.SchemeChoice("block", (16, 16)))]


def _compiled_moe(dtype, seed=0, keep_prob=0.4):
    cfg = configs.get("mixtral-8x7b", smoke=True)
    params = M.cast_tree(T.init_lm(jax.random.PRNGKey(seed), cfg), dtype)
    masks = RW.random_block_masks(params, MOE_SPEC, (16, 16),
                                  keep_prob=keep_prob, seed=seed)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, MOE_SPEC)
    packed = [r["path"] for r in report if r["packed"]]
    assert {"layers/moe/gate/w", "layers/moe/up/w",
            "layers/moe/down/w"} <= set(packed), report
    return cfg, pm, exec_params


def test_moe_sparse_parity_fp32():
    """Packed expert execution == dense-masked moe(), bit-close in fp32:
    the three expert GEMMs run through the vmapped BCS kernel."""
    cfg, pm, exec_params = _compiled_moe(jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    ld, _ = T.forward(pm, cfg, tokens)
    ls, _ = T.forward(exec_params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls),
                               rtol=2e-5, atol=2e-5)


def test_moe_sparse_parity_bf16():
    """bf16 params: fp32-accumulating kernel with the silu fused into the
    gate epilogue tracks the dense path to bf16 tolerance (one rounding
    instead of two, exactly as for layers.ffn)."""
    cfg, pm, exec_params = _compiled_moe(jnp.bfloat16)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    ld, _ = T.forward(pm, cfg, tokens)
    ls, _ = T.forward(exec_params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(ld, np.float32),
                               np.asarray(ls, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_moe_packed_generate_matches_dense_masked():
    cfg, pm, exec_params = _compiled_moe(jnp.float32, seed=3)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    ref = generate(pm, cfg, tokens, 4)
    out = generate(exec_params, cfg, tokens, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- MoE capacity / dispatch dtype regressions --------------------------------

def test_moe_tiny_group_capacity_clamped(monkeypatch):
    """Regression lock: for Sg < 4 the capacity floor of 4 must stay
    clamped to the group size before dispatch — a tiny group would
    otherwise hand _dispatch_tensors more slots than tokens.  Spies on
    the capacity actually passed to _dispatch_tensors (shape/finiteness
    alone can't distinguish an unclamped capacity)."""
    import repro.models.moe as moe_mod
    D, F, E = 16, 32, 4
    params = moe_mod.moe_init(jax.random.PRNGKey(0), D, F, E,
                              dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, D), jnp.float32)
    seen = {}
    orig = moe_mod._dispatch_tensors

    def spy(logits, top_k, capacity):
        seen["C"] = capacity
        return orig(logits, top_k, capacity)

    monkeypatch.setattr(moe_mod, "_dispatch_tensors", spy)
    out, aux = moe_mod.moe(params, x, top_k=2, group=2)     # Sg = 2 < 4
    assert seen["C"] == 2                   # clamped to Sg, not floor of 4
    assert out.shape == (1, 2, D)
    assert np.all(np.isfinite(np.asarray(out)))


def test_moe_dispatch_one_hot_fp32_under_bf16():
    """Externally supplied bf16 logits must be normalized to fp32 before
    softmax/top_k, so the expert choice (and hence dispatch/combine) is
    identical to routing the same values in fp32."""
    from repro.models.moe import _dispatch_tensors
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4),
                               jnp.bfloat16)
    d_bf, c_bf, _ = _dispatch_tensors(logits, 2, 4)
    d_f32, c_f32, _ = _dispatch_tensors(logits.astype(jnp.float32), 2, 4)
    assert d_bf.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(d_bf), np.asarray(d_f32))
    np.testing.assert_array_equal(np.asarray(c_bf), np.asarray(c_f32))


# -- SSM: in/out projections as PackedLayout producers ------------------------

SSM_SPEC = [(r"ssm/(in_proj|out_proj)/w", RW.SchemeChoice("block", (16, 8)))]


def _compiled_ssm(seed=0, keep_dense=True):
    cfg = configs.get("mamba2-1.3b", smoke=True)
    params = M.cast_tree(T.init_lm(jax.random.PRNGKey(seed), cfg),
                         jnp.float32)
    masks = RW.random_block_masks(params, SSM_SPEC, (16, 8), keep_prob=0.5,
                                  seed=seed)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(
        pm, masks, SSM_SPEC, spec=CompileSpec(keep_dense=keep_dense))
    packed = {r["path"] for r in report if r["packed"]}
    assert {"layers/ssm/in_proj/w", "layers/ssm/out_proj/w"} <= packed, \
        report
    return cfg, pm, exec_params


def test_ssm_packed_forward_parity():
    """Packed SSM projections (stacked over the scanned layer axis) ==
    dense-masked mixer: the in_proj (z/xBC/dt streams) and out_proj GEMMs
    run through the Pallas kernel inside the layer scan."""
    cfg, pm, exec_params = _compiled_ssm()
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    ld, _ = T.forward(pm, cfg, tokens)
    ls, _ = T.forward(exec_params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls),
                               rtol=2e-5, atol=2e-5)


def test_ssm_packed_generate_matches_dense_masked():
    """Prefill + O(1)-state decode through the packed projections: the
    fused scan decode loop emits the same tokens as the masked-dense path,
    and keep_dense=False (geometry read from the layout, not "w") too."""
    cfg, pm, exec_params = _compiled_ssm(seed=2)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    ref = generate(pm, cfg, tokens, 4)
    out = generate(exec_params, cfg, tokens, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    _, _, served = _compiled_ssm(seed=2, keep_dense=False)
    assert "w" not in served["layers"]["ssm"]["in_proj"]
    out2 = generate(served, cfg, tokens, 4)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))


# -- fused decode loop == eager python loop ----------------------------------

@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b"])
def test_generate_scan_matches_python_loop(arch):
    cfg = configs.get(arch, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, 2, 16, cfg.vocab)
    o_fused = generate(params, cfg, b["tokens"], 8)
    o_eager = generate_python(params, cfg, b["tokens"], 8)
    np.testing.assert_array_equal(np.asarray(o_fused), np.asarray(o_eager))


def test_generate_scan_matches_python_loop_sampled():
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, 2, 12, cfg.vocab)
    key = jax.random.PRNGKey(11)
    o_fused = generate(params, cfg, b["tokens"], 6, temperature=0.7, key=key)
    o_eager = generate_python(params, cfg, b["tokens"], 6, temperature=0.7,
                              key=key)
    np.testing.assert_array_equal(np.asarray(o_fused), np.asarray(o_eager))
