"""Fault injection against the AOT artifact store (serve.artifacts).

Every corruption class an on-disk artifact can suffer — bit flips,
truncation, stale digests, format-version skew, tampered manifests,
invariant-violating layouts, crashed half-written publishes — must be
DETECTED (structured ``ArtifactError``/``LayoutError``) and must degrade
to a fresh pack whose execution tree is bit-identical to a cold compile.
The warm path itself must also be bit-identical.  A corrupted artifact
may cost a repack; it may never mis-execute."""
import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import regularity as R
from repro.core import reweighted as RW
from repro.core import validate as V
from repro.kernels import ops
from repro.serve import artifacts as ART
from repro.serve.compile import CompileSpec, compile_model
from repro.train.trainer import apply_masks

SPEC = [(r"ffn/(gate|up)/w", RW.SchemeChoice("block", (16, 16))),
        (r"conv/w", RW.SchemeChoice("pattern", connectivity=0.5))]


def small_model(seed=0):
    key = jax.random.PRNGKey(seed)
    params = {
        "blk": {"ffn": {
            "gate": {"w": jax.random.normal(key, (64, 96), jnp.float32)},
            "up": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                          (64, 96), jnp.float32)}}},
        "conv": {"w": jax.random.normal(jax.random.fold_in(key, 2),
                                        (16, 8, 3, 3), jnp.float32)},
        "head": {"w": jax.random.normal(jax.random.fold_in(key, 3),
                                        (64, 32), jnp.float32)},
    }
    masks = RW.random_block_masks(params, [SPEC[0]], (16, 16),
                                  keep_prob=0.4)
    masks["conv"] = {"w": R.pattern_mask(params["conv"]["w"],
                                         connectivity_rate=0.5)}
    return apply_masks(params, masks), masks


def assert_trees_identical(a, b):
    la, sa = jax.tree_util.tree_flatten(a)
    lb, sb = jax.tree_util.tree_flatten(b)
    assert sa == sb
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture
def store(tmp_path):
    """A populated artifact store + (pm, masks, key, cold exec tree)."""
    ops.clear_pack_cache()
    pm, masks = small_model()
    cold, report = compile_model(pm, masks, SPEC, artifact_dir=tmp_path)
    key = ART.model_digest(pm, masks, SPEC)
    assert (tmp_path / key / ART.MANIFEST_FILE).exists()
    return tmp_path, pm, masks, key, cold


def warm_compile(tmp_path, pm, masks):
    ops.clear_pack_cache()
    return compile_model(pm, masks, SPEC, artifact_dir=tmp_path)


# -- happy path --------------------------------------------------------------

def test_warm_load_bit_identical_to_cold(store):
    tmp_path, pm, masks, key, cold = store
    misses_before = ops.pack_cache_stats()["misses"]
    warm, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, warm)
    # and the load really came from disk: no new packs happened
    assert ops.pack_cache_stats()["misses"] == misses_before


def test_load_artifact_validates_layouts(store):
    tmp_path, _, _, key, cold = store
    layers, report = ART.load_artifact(tmp_path, key)
    assert layers and all(
        V.validate_layout(lo) is lo for lo in layers.values())
    assert any(r["packed"] for r in report)


def test_digest_covers_weights_and_options(store):
    tmp_path, pm, masks, key, _ = store
    bumped = jax.tree_util.tree_map(lambda x: x, pm)
    bumped["head"]["w"] = pm["head"]["w"] + 1.0
    assert ART.model_digest(bumped, masks, SPEC) != key
    assert ART.model_digest(pm, masks, SPEC,
                            spec=CompileSpec(n_bins=2)) != key
    assert ART.model_digest(pm, masks, SPEC) == key     # deterministic


# -- corruption classes ------------------------------------------------------

def flip_bit(path, offset=100):
    raw = bytearray(path.read_bytes())
    raw[offset] ^= 0x40
    path.write_bytes(bytes(raw))


def test_bitflip_in_arrays_detected_and_falls_back(store, caplog):
    tmp_path, pm, masks, key, cold = store
    flip_bit(tmp_path / key / ART.ARRAYS_FILE)
    with pytest.raises(ART.ArtifactChecksumError):
        ART.load_artifact(tmp_path, key)
    with caplog.at_level(logging.WARNING, "repro.serve.artifacts"):
        repacked, _ = warm_compile(tmp_path, pm, masks)
    assert "fresh pack" in caplog.text and "checksum" in caplog.text
    assert_trees_identical(cold, repacked)


def test_truncated_arrays_detected(store):
    tmp_path, pm, masks, key, cold = store
    f = tmp_path / key / ART.ARRAYS_FILE
    f.write_bytes(f.read_bytes()[:64])
    with pytest.raises(ART.ArtifactChecksumError):
        ART.load_artifact(tmp_path, key)
    repacked, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, repacked)


def test_stale_digest_changed_weights_is_a_miss(store, caplog):
    """Weights changed since publish -> different digest -> clean miss +
    fresh pack; the stale artifact is simply not selected."""
    tmp_path, pm, masks, key, _ = store
    pm2 = jax.tree_util.tree_map(lambda x: x, pm)
    pm2["head"]["w"] = pm["head"]["w"] * 2.0
    with caplog.at_level(logging.INFO, "repro.serve.artifacts"):
        ops.clear_pack_cache()
        fresh, _ = compile_model(pm2, masks, SPEC, artifact_dir=tmp_path)
    assert "[missing]" in caplog.text
    ops.clear_pack_cache()
    cold2, _ = compile_model(pm2, masks, SPEC)
    assert_trees_identical(fresh, cold2)


def test_tampered_pack_key_is_digest_mismatch(store):
    tmp_path, pm, masks, key, cold = store
    mpath = tmp_path / key / ART.MANIFEST_FILE
    man = json.loads(mpath.read_text())
    man["pack_key"] = "0" * len(key)
    mpath.write_text(json.dumps(man))
    with pytest.raises(ART.ArtifactDigestMismatch):
        ART.load_artifact(tmp_path, key)
    repacked, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, repacked)


def test_version_skew_detected(store):
    tmp_path, pm, masks, key, cold = store
    mpath = tmp_path / key / ART.MANIFEST_FILE
    man = json.loads(mpath.read_text())
    man["format_version"] = ART.FORMAT_VERSION + 1
    mpath.write_text(json.dumps(man))
    with pytest.raises(ART.ArtifactVersionSkew):
        ART.load_artifact(tmp_path, key)
    repacked, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, repacked)


def test_unreadable_manifest_is_corrupt(store):
    tmp_path, pm, masks, key, cold = store
    (tmp_path / key / ART.MANIFEST_FILE).write_text("{not json")
    with pytest.raises(ART.ArtifactCorrupt):
        ART.load_artifact(tmp_path, key)
    repacked, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, repacked)


def test_missing_manifest_is_corrupt(store):
    tmp_path, _, _, key, _ = store
    (tmp_path / key / ART.MANIFEST_FILE).unlink()
    with pytest.raises(ART.ArtifactCorrupt):
        ART.load_artifact(tmp_path, key)


def test_invariant_violation_with_valid_checksums(store):
    """The nastiest case: rewrite arrays.npz with an out-of-range k_idx
    AND recompute the manifest checksums, so only layout validation can
    catch it.  Must raise LayoutError on load and repack identically."""
    tmp_path, pm, masks, key, cold = store
    adir = tmp_path / key
    data = dict(np.load(adir / ART.ARRAYS_FILE))
    kname = next(k for k in data if "::k_idx." in k)
    arr = data[kname].copy()
    arr.flat[0] = 10_000                  # far outside any Kb
    data[kname] = arr
    np.savez(adir / ART.ARRAYS_FILE, **data)
    mpath = adir / ART.MANIFEST_FILE
    man = json.loads(mpath.read_text())
    apath = adir / ART.ARRAYS_FILE
    man["files"][ART.ARRAYS_FILE] = {
        "sha256": ART.file_checksum(apath),
        "bytes": apath.stat().st_size,
    }
    mpath.write_text(json.dumps(man))
    with pytest.raises(V.LayoutError):
        ART.load_artifact(tmp_path, key)
    repacked, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, repacked)


def test_crashed_writer_husk_is_ignored(store):
    """A dead writer's .tmp_* staging dir must not shadow the artifact or
    break the next publish."""
    tmp_path, pm, masks, key, cold = store
    husk = tmp_path / f".tmp_{key}_dead"
    husk.mkdir()
    (husk / ART.ARRAYS_FILE).write_bytes(b"partial")
    warm, _ = warm_compile(tmp_path, pm, masks)
    assert_trees_identical(cold, warm)


def test_concurrent_publish_race_keeps_existing(store):
    """save_artifact into an already-published digest is a no-op."""
    tmp_path, pm, masks, key, cold = store
    before = (tmp_path / key / ART.MANIFEST_FILE).read_bytes()
    layers, report = ART.load_artifact(tmp_path, key)
    ART.save_artifact(tmp_path, key, cold, report)
    assert (tmp_path / key / ART.MANIFEST_FILE).read_bytes() == before


def test_save_refuses_invalid_layout(store, tmp_path_factory):
    """Publish-side validation: a corrupted in-memory layout never makes
    it to disk."""
    import dataclasses
    tmp_path, pm, masks, key, cold = store
    layers, report = ART.load_artifact(tmp_path, key)
    lpath, layout = next(iter(layers.items()))
    k = np.array(layout.k_idx[0]).copy()
    k.flat[0] = -3
    bad = dataclasses.replace(
        layout, k_idx=(k,) + layout.k_idx[1:])
    broken = jax.tree_util.tree_map(lambda x: x, cold)
    node = broken
    for part in lpath.split("/"):
        node = node[part]
    node["packed"] = bad
    out = tmp_path_factory.mktemp("resave")
    with pytest.raises(V.LayoutError):
        ART.save_artifact(out, key, broken, report)
    assert not (out / key).exists()


def test_bf16_roundtrip_exact(tmp_path):
    """bf16 layouts widen to f32 on disk and recast on load — lossless."""
    ops.clear_pack_cache()
    pm, masks = small_model(seed=3)
    pm = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16)
        if x.dtype == jnp.float32 else x, pm)
    cold, _ = compile_model(pm, masks, SPEC, artifact_dir=tmp_path)
    ops.clear_pack_cache()
    warm, _ = compile_model(pm, masks, SPEC, artifact_dir=tmp_path)
    assert_trees_identical(cold, warm)
    layers, _ = ART.load_artifact(
        tmp_path, ART.model_digest(pm, masks, SPEC))
    assert layers and all(
        jnp.asarray(lo.values[0]).dtype == jnp.bfloat16
        for lo in layers.values())


# -- pack cache bound (satellite b) ------------------------------------------

def test_pack_cache_eviction_is_bounded_and_logged(caplog):
    ops.clear_pack_cache()
    old = ops.configure_pack_cache()        # snapshot caps BEFORE tightening
    ops.configure_pack_cache(max_entries=2)
    try:
        with caplog.at_level(logging.INFO, "repro.kernels.ops"):
            for seed in range(3):
                w = np.asarray(jax.random.normal(
                    jax.random.PRNGKey(seed), (32, 32), jnp.float32))
                mask = np.ones((32, 32), np.float32)
                ops.pack(w, mask, (16, 16))
        stats = ops.pack_cache_stats()
        assert stats["entries"] == 2
        assert stats["evictions"] == 1
        assert "pack cache evict" in caplog.text
    finally:
        ops.configure_pack_cache(max_entries=old["max_entries"],
                                 max_bytes=old["max_bytes"])
        ops.clear_pack_cache()
