"""Graceful fallback when ``hypothesis`` is not installed.

Provides just enough of the ``given``/``settings``/``strategies`` surface
for this repo's property tests to run as deterministic example sweeps: each
strategy exposes a small fixed example list and ``given`` zips through them
round-robin.  Coverage is obviously thinner than real hypothesis — install
``requirements-dev.txt`` for the real thing — but the tier-1 suite stays
runnable (and still exercises every property body) on a clean container.
"""
from __future__ import annotations




class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


class st:
    @staticmethod
    def sampled_from(xs):
        return _Strategy(xs)

    @staticmethod
    def floats(lo, hi):
        return _Strategy([lo, (3 * lo + hi) / 4, (lo + hi) / 2, hi])

    @staticmethod
    def integers(lo, hi):
        return _Strategy([lo, lo + (hi - lo) // 3, lo + 2 * (hi - lo) // 3,
                          hi])

    @staticmethod
    def booleans():
        return _Strategy([False, True])


def settings(*_args, **_kw):
    def deco(fn):
        return fn
    return deco


def given(**strategies):
    """Run the test once per round-robin combination of strategy examples.
    The sweep length is the max example-list length (each list cycles), so
    every example of every strategy appears at least once."""
    def deco(fn):
        # NOTE: no functools.wraps — pytest would follow __wrapped__ to the
        # original signature and demand fixtures for the strategy params.
        def wrapper():
            n = max(len(s.examples) for s in strategies.values())
            for i in range(n):
                picked = {name: s.examples[i % len(s.examples)]
                          for name, s in strategies.items()}
                fn(**picked)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
