"""Reweighted dynamic regularization tests (paper §4.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reweighted as RW
from repro.core.reweighted import SchemeChoice


def toy_params(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"a": {"w": jax.random.normal(k1, (32, 64))},
            "b": {"w": jax.random.normal(k2, (64, 16))},
            "norm": {"scale": jnp.ones((64,))}}


SPEC = [(r"a/w", SchemeChoice("block", (8, 16))),
        (r"b/w", SchemeChoice("structured_row"))]


def test_alphas_inverse_of_norms():
    p = toy_params()
    cfg = RW.ReweightedConfig(spec=tuple(SPEC), eps=1e-4)
    alphas = RW.update_alphas(p, cfg)
    sq = RW.group_sqnorms(p["a"]["w"], SPEC[0][1])["row"]
    np.testing.assert_allclose(np.asarray(alphas["a/w"]["row"]),
                               1.0 / (np.asarray(sq) + 1e-4), rtol=1e-5)


def test_penalty_positive_and_differentiable():
    p = toy_params()
    cfg = RW.ReweightedConfig(spec=tuple(SPEC))
    alphas = RW.init_alphas(p, SPEC)
    val, grads = jax.value_and_grad(
        lambda pp: RW.penalty(pp, alphas, cfg))(p)
    assert val > 0
    assert float(jnp.abs(grads["a"]["w"]).sum()) > 0
    # norm params are not in the spec -> zero gradient
    assert float(jnp.abs(grads["norm"]["scale"]).sum()) == 0


def test_penalty_drives_groups_to_zero():
    """Gradient descent on the reweighted penalty alone shrinks the
    weakest groups fastest — the mechanism behind automatic rates."""
    p = toy_params()
    cfg = RW.ReweightedConfig(spec=tuple(SPEC), lam=1.0)
    alphas = RW.update_alphas(p, cfg)
    g = jax.grad(lambda pp: RW.penalty(pp, alphas, cfg))(p)
    w, gw = p["a"]["w"], g["a"]["w"]
    sq = np.asarray(RW.group_sqnorms(w, SPEC[0][1])["row"]).reshape(-1)
    # relative shrink rate per group ~ alpha ~ 1/norm: weakest shrink most
    rel = np.asarray(
        RW.group_sqnorms(gw / (jnp.abs(w) + 1e-9) * jnp.sign(w),
                         SPEC[0][1])["row"]).reshape(-1)
    weak, strong = np.argmin(sq), np.argmax(sq)
    assert rel[weak] > rel[strong]


def test_global_threshold_auto_rates():
    """One global tau -> per-layer compression rates emerge automatically
    and differ between layers (Table 1 'Auto')."""
    p = toy_params()
    p["a"]["w"] = p["a"]["w"] * 0.1     # layer a much weaker
    tau = RW.global_threshold(p, SPEC, target_rate=0.5)
    masks = RW.masks_for_spec(p, SPEC, threshold=tau)
    rep = RW.sparsity_report(p, masks)
    assert rep["a/w"]["density"] < rep["b/w"]["density"]


def test_masks_structure_matches_params():
    p = toy_params()
    masks = RW.masks_for_spec(p, SPEC, default_rate=0.5)
    assert jax.tree_util.tree_structure(masks) == \
        jax.tree_util.tree_structure(p)
    assert masks["norm"]["scale"].shape == ()       # sentinel
    assert masks["a"]["w"].shape == p["a"]["w"].shape


def test_apply_masks_zeros_stay_zero_after_grad_step():
    from repro.train.trainer import apply_masks
    p = toy_params()
    masks = RW.masks_for_spec(p, SPEC, default_rate=0.5)
    mp = apply_masks(p, masks)
    assert float(jnp.sum(jnp.abs(mp["a"]["w"]) *
                         (1 - masks["a"]["w"]))) == 0.0
