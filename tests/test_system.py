"""End-to-end system behaviour tests: the full paper pipeline (map ->
reweighted train -> threshold -> finetune -> BCS pack -> sparse execute)
on CPU-sized models."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import reweighted as RW
from repro.core import pruner
from repro.core.mapper_rule import lm_layers, map_rules
from repro.core.reweighted import SchemeChoice
from repro.data.pipeline import synthetic_batch
from repro.models import layers as ML
from repro.models import transformer as T
from repro.train.trainer import make_train_step, apply_masks


def small_spec(spec, block=(8, 16)):
    return [(p, SchemeChoice(c.scheme, block) if c.scheme != "none" else c)
            for p, c in spec]


def test_full_prune_pipeline_compresses_without_blowing_up_loss():
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    layers = lm_layers(cfg, tokens=256)
    spec = small_spec(map_rules(layers, dataset_hard=False)[0])
    rw = RW.ReweightedConfig(spec=tuple(spec), lam=1e-3)
    opt_init, step = make_train_step(cfg, lr=3e-3, reweighted=rw)
    opt = opt_init(params)
    step = jax.jit(step)
    bf = lambda s: synthetic_batch(0, s, 8, 32, cfg.vocab)

    res = pruner.reweighted_prune(params, opt, spec, step, bf,
                                  lam=1e-3, steps=60, reweight_every=15,
                                  target_rate=0.25, finetune_steps=60)
    overall = res.report["__overall__"]
    assert overall["compression"] > 1.5
    # pruned weights are exactly zero
    flat_m = jax.tree_util.tree_leaves(res.masks)
    assert any(m.ndim > 0 and float(m.min()) == 0 for m in flat_m)
    # the pruned model still predicts (well below the ln(V)=5.545
    # uniform floor on this vocab=256 task)
    def loss(p, b):
        logits, _ = T.forward(p, cfg, b["tokens"])
        return float(ML.cross_entropy(logits, b["labels"]))
    lp = loss(res.params, bf(999))
    assert lp < 5.4, lp


def test_pruned_model_executes_on_bcs_kernel():
    """Serving path: pack a pruned projection into BCS and check the Pallas
    kernel output matches the masked-dense forward."""
    from repro.core import regularity as R
    from repro.kernels import ops, ref
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 256), jnp.float32)
    mask = R.make_mask(w, "block_row", block=(64, 64), rate=0.7)
    packed = ops.pack(w, mask, (64, 64))
    assert packed.density <= 1.0
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 128), jnp.float32)
    y_sparse = ops.sparse_linear(x, packed=packed, bm=64)
    y_dense = ref.masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(y_dense),
                               rtol=1e-3, atol=1e-3)


def test_masked_training_preserves_sparsity():
    """Gradient updates through masks never resurrect pruned weights."""
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    layers = lm_layers(cfg, tokens=256)
    spec = small_spec(map_rules(layers, dataset_hard=False)[0])
    masks = RW.masks_for_spec(params, spec, default_rate=0.5)
    opt_init, step = make_train_step(cfg, lr=1e-2)
    opt = opt_init(params)
    step = jax.jit(step)
    for i in range(5):
        b = synthetic_batch(0, i, 4, 32, cfg.vocab)
        params, opt, _ = step(params, opt, b, masks, None)
    mp = apply_masks(params, masks)
    m = masks["layers"]["ffn"]["gate"]["w"]
    w = mp["layers"]["ffn"]["gate"]["w"]
    assert float(jnp.sum(jnp.abs(w.astype(jnp.float32)) * (1 - m))) == 0.0


def test_hybrid_mapping_beats_single_scheme_latency():
    """Table 2's punchline: a hybrid per-layer mapping is at least as fast
    as uniform unstructured pruning under the latency model."""
    from repro.core.mapper_rule import total_latency
    from repro.core.latency_model import matmul_latency
    cfg = configs.get("mixtral-8x7b")
    layers = lm_layers(cfg, tokens=32768)
    _, rep_hybrid = map_rules(layers, dataset_hard=True, compression=8.0)
    t_hybrid = total_latency(rep_hybrid)
    t_unstructured = sum(
        matmul_latency(l.M, l.K, l.N, scheme="unstructured",
                       compression=8.0) * l.count
        for l in layers if l.kind == "fc")
    assert t_hybrid < t_unstructured


def test_checkpoint_restart_mid_training(tmp_path):
    """Kill/restart: state restores and training continues (the
    fault-tolerance story end-to-end)."""
    from repro.distributed import checkpoint as CKPT
    cfg = configs.get("yi-9b", smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    opt_init, step = make_train_step(cfg, lr=1e-3)
    opt = opt_init(params)
    step = jax.jit(step)
    for i in range(3):
        b = synthetic_batch(0, i, 4, 32, cfg.vocab)
        params, opt, m0 = step(params, opt, b)
    CKPT.save(tmp_path, 3, {"params": params, "opt": opt})
    restored, s = CKPT.restore(tmp_path, {"params": params, "opt": opt})
    assert s == 3
    b = synthetic_batch(0, 3, 4, 32, cfg.vocab)
    _, _, m1 = step(restored["params"], restored["opt"], b)
    assert np.isfinite(float(m1["loss"]))


def test_deterministic_data_pipeline():
    """Straggler story precondition: batches are pure functions of
    (seed, step, shard)."""
    b1 = synthetic_batch(0, 5, 4, 16, 100, shard=2)
    b2 = synthetic_batch(0, 5, 4, 16, 100, shard=2)
    b3 = synthetic_batch(0, 6, 4, 16, 100, shard=2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
