"""Numerical correctness of the compute layers against naive oracles:
flash-chunked attention, capacity-dispatch MoE, chunked SSD scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import ssm as S
from repro.models.moe import moe, moe_init, _dispatch_tensors


# -- attention ----------------------------------------------------------------

def naive_attention_h(q, k, v, q_pos, k_pos, causal=True, window=0):
    """O(S^2) reference in H-form: q,k,v (B,S,H,hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqhe,bshe->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshe->bqhe", p, v.astype(jnp.float32))


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("kv_chunk", [4, 16, 32])
def test_flash_attention_matches_naive(window, kv_chunk):
    B, Sq, KV, H, hd = 2, 32, 2, 6, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = A._expand_kv(jax.random.normal(ks[1], (B, Sq, KV, hd)), H)
    v = A._expand_kv(jax.random.normal(ks[2], (B, Sq, KV, hd)), H)
    pos = jnp.arange(Sq, dtype=jnp.int32)
    out = A.attend(q, k, v, pos, pos, causal=True, window=window,
                   kv_chunk=kv_chunk)
    ref = naive_attention_h(q, k, v, pos, pos, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_expand_kv_repeats_groups():
    k = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 8))
    kf = A._expand_kv(k, 6)
    assert kf.shape == (1, 4, 6, 8)
    np.testing.assert_allclose(np.asarray(kf[:, :, 0]),
                               np.asarray(kf[:, :, 2]))
    np.testing.assert_allclose(np.asarray(kf[:, :, 0]),
                               np.asarray(k[:, :, 0]))


def test_cached_decode_matches_naive():
    B, Sk, KV, G, hd = 2, 16, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, KV, G, hd))
    k = jax.random.normal(ks[1], (B, Sk, KV, hd))
    v = jax.random.normal(ks[2], (B, Sk, KV, hd))
    k_pos = jnp.arange(Sk, dtype=jnp.int32)
    q_pos = jnp.asarray([Sk - 1], jnp.int32)
    out = A.attend_cached(q, k, v, q_pos, k_pos)
    # direct oracle: full softmax over exactly the cache
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32) * hd ** -0.5,
                   k.astype(jnp.float32))
    p = jax.nn.softmax(s, -1)
    expect = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), rtol=1e-3,
                               atol=1e-5)


def test_rotary_preserves_norm_and_relativity():
    from repro.models import layers as L
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)
    r = L.apply_rotary(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(p):
        rq = L.apply_rotary(q, jnp.asarray([p]))
        rk = L.apply_rotary(k, jnp.asarray([p + 3]))
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(0) - dot_at(11)) < 1e-3


# -- MoE -----------------------------------------------------------------------

def test_moe_matches_dense_expert_compute_at_high_capacity():
    """With capacity >= tokens, capacity dispatch == exact top-k MoE."""
    D, F, E, K = 16, 32, 4, 2
    params = moe_init(jax.random.PRNGKey(0), D, F, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D), jnp.float32)
    out, aux = moe(params, x, top_k=K, capacity_factor=64.0, group=16)

    # naive: run every expert densely, combine by normalized top-k probs
    logits = jnp.einsum("bsd,de->bse", x, params["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    gv, idx = jax.lax.top_k(probs, K)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    def expert(e, xx):
        g = xx @ params["gate"]["w"][e]
        u = xx @ params["up"]["w"][e]
        return (jax.nn.silu(g) * u) @ params["down"]["w"][e]
    ref = jnp.zeros_like(x)
    for e in range(E):
        w_e = jnp.sum(jnp.where(idx == e, gv, 0.0), -1)
        ref = ref + w_e[..., None] * expert(e, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_tokens():
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 4))
    disp, comb, aux = _dispatch_tensors(logits, top_k=2, capacity=4)
    per_expert = np.asarray(disp.sum(axis=(1, 3)))
    assert (per_expert <= 4 + 1e-6).all()
    assert float(aux) > 0


# -- SSD -----------------------------------------------------------------------

def naive_ssd(xh, dt, A_, Bm, Cm):
    """Token-by-token linear recurrence (the SSD definition)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A_)                       # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], xh[:, t], Bm[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Cm[:, t]))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_ssd_matches_naive_recurrence(chunk):
    B, seq, H, P, N = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = jax.random.normal(ks[0], (B, seq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, seq, H)))
    A_ = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, seq, H, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(5), (B, seq, H, N)) * 0.5
    y, h = S._ssd_scan(xh, dt, A_, Bm, Cm, chunk=chunk)
    y_ref, h_ref = naive_ssd(xh, dt, A_, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)
