"""Layout-invariant validation (core.validate): every corruption class of a
``PackedLayout``/``TapLayout`` raises the matching ``LayoutError`` subclass,
and freshly packed layouts pass clean.  These are the invariants the AOT
artifact loader relies on to refuse a corrupted file instead of serving
wrong outputs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core import validate as V
from repro.kernels import ops


def packed_case(reorder=True, n_bins=4, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = np.asarray(jax.random.normal(k1, (128, 256), jnp.float32))
    keep = np.asarray(jax.random.uniform(k2, (8, 16))) > 0.6
    mask = np.repeat(np.repeat(keep, 16, 0), 16, 1).astype(np.float32)
    return ops.pack(w * mask, mask, (16, 16), reorder=reorder,
                    n_bins=n_bins, use_cache=False)


def conv_packed_case(seed=0):
    kh, kw, cin, cout = 3, 3, 16, 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = np.asarray(jax.random.normal(k1, (kh * kw * cin, cout), jnp.float32))
    keep = np.asarray(jax.random.uniform(k2, (kh * kw * cin // 8,
                                              cout // 8))) > 0.5
    mask = np.repeat(np.repeat(keep, 8, 0), 8, 1).astype(np.float32)
    return ops.pack(w * mask, mask, (8, 8), reorder=True, n_bins=2,
                    conv=(kh, kw, cin), use_cache=False)


def tap_case(connectivity=0.5, n_bins=4, seed=0):
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                     (16, 8, 3, 3), jnp.float32))
    mask = np.asarray(R.pattern_mask(w, connectivity_rate=connectivity))
    return ops.pack_taps(w * mask, mask, n_bins=n_bins, use_cache=False)


def replace_leaf(layout, field, b, new):
    """dataclasses.replace with bin ``b`` of tuple-of-arrays ``field``
    swapped for ``new`` (None b replaces the whole field)."""
    if b is None:
        return dataclasses.replace(layout, **{field: new})
    old = getattr(layout, field)
    return dataclasses.replace(
        layout, **{field: old[:b] + (new,) + old[b + 1:]})


# -- clean layouts pass ------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: packed_case(reorder=True),
    lambda: packed_case(reorder=False, n_bins=1),
    conv_packed_case,
    tap_case,
    lambda: tap_case(connectivity=0.0, n_bins=1),
])
def test_fresh_layouts_validate_clean(make):
    layout = make()
    assert V.validate_layout(layout, path="t") is layout


def test_validate_rejects_non_layout():
    with pytest.raises(V.LayoutStructureError):
        V.validate_layout({"values": ()}, path="t")


# -- PackedLayout violations -------------------------------------------------

def test_packed_block_must_divide_shape():
    bad = dataclasses.replace(packed_case(), shape=(120, 256))
    with pytest.raises(V.LayoutGeometryError):
        V.validate_layout(bad)


def test_packed_bin_sizes_must_tile_columns():
    layout = packed_case()
    v0 = np.asarray(layout.values[0])
    bad = replace_leaf(layout, "values", 0, v0[:-1])   # drop a column
    bad = replace_leaf(bad, "k_idx", 0, np.asarray(bad.k_idx[0])[:-1])
    with pytest.raises(V.LayoutGeometryError):
        V.validate_layout(bad)


def test_packed_k_idx_out_of_range():
    layout = packed_case()
    k = np.array(layout.k_idx[0]).copy()
    k.flat[0] = layout.Kb                              # one past the end
    with pytest.raises(V.LayoutIndexError) as ei:
        V.validate_layout(replace_leaf(layout, "k_idx", 0, k), path="lyr")
    assert ei.value.code == "index_range" and ei.value.path == "lyr"


def test_packed_negative_k_idx():
    layout = packed_case()
    k = np.array(layout.k_idx[0]).copy()
    k.flat[0] = -1
    with pytest.raises(V.LayoutIndexError):
        V.validate_layout(replace_leaf(layout, "k_idx", 0, k))


def test_packed_nnz_exceeds_bin_degree():
    layout = packed_case()
    n = np.array(layout.nnz).copy()
    n[0] = layout.bin_degrees[0] + 1
    with pytest.raises(V.LayoutCountError):
        V.validate_layout(dataclasses.replace(layout, nnz=n))


def test_packed_nnz_negative():
    layout = packed_case()
    n = np.array(layout.nnz).copy()
    n[0] = -1
    with pytest.raises(V.LayoutCountError):
        V.validate_layout(dataclasses.replace(layout, nnz=n))


def test_packed_perm_not_inverse():
    layout = packed_case()
    assert layout.perm is not None
    ip = np.array(layout.inv_perm).copy()
    ip[[0, 1]] = ip[[1, 0]]                            # break the inverse
    with pytest.raises(V.LayoutPermutationError):
        V.validate_layout(dataclasses.replace(layout, inv_perm=ip))


def test_packed_perm_not_a_permutation():
    layout = packed_case()
    p = np.array(layout.perm).copy()
    p[0] = p[1]                                        # duplicate entry
    with pytest.raises(V.LayoutPermutationError):
        V.validate_layout(dataclasses.replace(layout, perm=p))


def test_packed_lone_perm_is_an_error():
    layout = packed_case()
    with pytest.raises(V.LayoutPermutationError):
        V.validate_layout(dataclasses.replace(layout, inv_perm=None))


def test_packed_values_k_idx_shape_mismatch():
    layout = packed_case()
    k = np.array(layout.k_idx[0])[..., :-1]            # truncate a slot
    with pytest.raises(V.LayoutStructureError):
        V.validate_layout(replace_leaf(layout, "k_idx", 0, k))


def test_conv_taps_must_match_geometry():
    layout = conv_packed_case()
    taps = list(layout.conv_taps)
    taps[0], taps[1] = taps[1], taps[0]                # swap two taps
    bad = dataclasses.replace(layout, conv_taps=tuple(taps))
    with pytest.raises(V.LayoutAuxError):
        V.validate_layout(bad)


def test_conv_taps_wrong_arity():
    layout = conv_packed_case()
    bad = dataclasses.replace(layout,
                              conv_taps=layout.conv_taps[:-1])
    with pytest.raises(V.LayoutAuxError):
        V.validate_layout(bad)


# -- TapLayout violations ----------------------------------------------------

def test_tap_t_idx_out_of_range():
    tap = tap_case()
    t = np.array(tap.t_idx[0]).copy()
    t.flat[0] = len(np.asarray(tap.alive))             # past the alive band
    with pytest.raises(V.LayoutIndexError):
        V.validate_layout(replace_leaf(tap, "t_idx", 0, t))


def test_tap_alive_out_of_range():
    tap = tap_case()
    alive = np.array(tap.alive).copy()
    alive[-1] = tap.shape[0]                           # K itself
    with pytest.raises(V.LayoutIndexError):
        V.validate_layout(dataclasses.replace(tap, alive=alive))


def test_tap_alive_must_be_sorted():
    tap = tap_case()
    alive = np.array(tap.alive).copy()
    if alive.size < 2:
        pytest.skip("degenerate alive band")
    alive[[0, 1]] = alive[[1, 0]]
    with pytest.raises(V.LayoutIndexError):
        V.validate_layout(dataclasses.replace(tap, alive=alive))


def test_tap_k_full_must_match_alive_gather():
    tap = tap_case()
    assert tap.k_full is not None
    kf = np.array(tap.k_full[0]).copy()
    kf.flat[0] = (kf.flat[0] + 1) % tap.shape[0]
    with pytest.raises(V.LayoutAuxError):
        V.validate_layout(replace_leaf(tap, "k_full", 0, kf))


def test_tap_nnz_exceeds_bin_degree():
    tap = tap_case()
    n = np.array(tap.nnz).copy()
    n[0] = tap.bin_degrees[0] + 1
    with pytest.raises(V.LayoutCountError):
        V.validate_layout(dataclasses.replace(tap, nnz=n))


def test_tap_group_must_divide():
    tap = tap_case()
    with pytest.raises(V.LayoutGeometryError):
        V.validate_layout(dataclasses.replace(tap, group=3))


# -- tree walk ---------------------------------------------------------------

def test_validate_tree_counts_and_tags_path():
    tree = {"blk": {"ffn": {"packed": packed_case()},
                    "conv": {"packed": tap_case(), "b": np.zeros(3)}},
            "head": {"w": np.zeros((4, 4))}}
    assert V.validate_tree(tree) == 2
    k = np.array(tree["blk"]["ffn"]["packed"].k_idx[0]).copy()
    k.flat[0] = -5
    tree["blk"]["ffn"]["packed"] = replace_leaf(
        tree["blk"]["ffn"]["packed"], "k_idx", 0, k)
    with pytest.raises(V.LayoutIndexError) as ei:
        V.validate_tree(tree)
    assert "blk/ffn/packed" in str(ei.value)


def test_roundtrip_after_validation_is_lossless():
    """Validation itself must not perturb the layout (pure check)."""
    layout = packed_case()
    before = np.asarray(BCS.layout_to_dense(layout)) \
        if hasattr(BCS, "layout_to_dense") else layout.to_dense()
    V.validate_layout(layout)
    after = layout.to_dense()
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
