"""Pattern/connectivity CONV layers through the tap-gather path:
``pattern_lower`` round-trips, packed-vs-masked-dense parity on both tiny
conv archs (incl. connectivity pruning and the 5x5 kernel), reorder
bit-identity through ``sparse_conv2d_pattern``, the compile_model routing
(a pattern pick compiles to a sparse producer, never the logged dense
fallback), and the mapper -> compile regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcs as BCS
from repro.core import mapper_rule as MR
from repro.core import regularity as R
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.models import convnet as C
from repro.serve.compile import (CompileSpec, compile_model,
                                 compiled_summary)
from repro.train.trainer import apply_masks

PATTERN_SPEC = [(r"(^|/)(c|pw|dw)\d+/w",
                 RW.SchemeChoice("pattern", connectivity=0.5))]


def pattern_case(P, Q, kh=3, kw=3, connectivity=0.0, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    if (kh, kw) == (3, 3):
        mask = R.pattern_mask(w, connectivity_rate=connectivity)
    else:
        mask = R.connectivity_mask(w, rate=connectivity)
    return w * mask, mask


def dense_conv(wm, x, stride):
    kernel = wm.transpose(2, 3, 1, 0)            # (kh,kw,Q,P)
    return jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# -- pattern_lower: round-trip + structure -----------------------------------

@pytest.mark.parametrize("connectivity,group,n_bins,reorder", [
    (0.0, 1, 4, True),
    (0.5, 1, 4, True),
    (0.5, 1, 1, True),
    (0.5, 4, 4, True),
    (0.5, 1, 4, False),
])
def test_pattern_lower_round_trip(connectivity, group, n_bins, reorder):
    """TapLayout.to_dense reconstructs exactly the lowered masked weight."""
    wm, mask = pattern_case(16, 8, connectivity=connectivity, seed=2)
    tap = BCS.pattern_lower(wm, mask, group=group, n_bins=n_bins,
                            reorder=reorder)
    np.testing.assert_array_equal(tap.to_dense(),
                                  BCS.conv_lower(np.asarray(wm)))


def test_pattern_lower_savings_are_executed_taps():
    """4-of-9 patterns without connectivity: every filter keeps exactly
    4*Q taps, so executed savings equal the exact 1 - 4/9 (no padding)."""
    wm, mask = pattern_case(16, 8, seed=1)
    tap = BCS.pattern_lower(wm, mask)
    assert tap.flops_saved == pytest.approx(1 - 4 / 9)
    assert tap.padding_overhead == pytest.approx(1.0)


def test_pattern_lower_drops_globally_dead_rows():
    """A channel pruned in EVERY filter leaves the alive band entirely —
    its taps are never gathered into the kernel input."""
    wm, mask = pattern_case(8, 8, seed=3)
    mask = np.array(mask)
    mask[:, 2] = 0.0                              # kill channel 2 everywhere
    wm = np.asarray(wm) * mask
    tap = BCS.pattern_lower(wm, mask)
    K = tap.shape[0]
    dead = {(t * 8 + 2) for t in range(9)}        # rows (i*Kw+j)*Q + q, q=2
    assert set(np.asarray(tap.alive).tolist()).isdisjoint(dead)
    assert tap.n_alive <= K - 9


# -- tap-gather kernel: parity vs the masked lax.conv oracle -----------------

@pytest.mark.parametrize("P,Q,kh,kw,stride,conn", [
    (32, 16, 3, 3, 1, 0.0),      # pure 4-of-9 patterns
    (32, 16, 3, 3, 2, 0.5),      # patterns + connectivity, stride 2
    (64, 32, 5, 5, 2, 0.5),      # non-3x3: connectivity-only, stride 2
    (32, 3, 3, 3, 1, 0.0),       # 3-channel stem (block-untileable)
])
def test_sparse_conv2d_pattern_matches_dense_conv(P, Q, kh, kw, stride,
                                                  conn):
    wm, mask = pattern_case(P, Q, kh, kw, connectivity=conn)
    tap = ops.pack_taps(wm, mask, n_bins=4)
    assert tap.flops_saved > 0.3                  # real executed-tap savings
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 12, Q), jnp.float32)
    y = ops.sparse_conv2d_pattern(x, tap, kh=kh, kw=kw, stride=stride)
    y_ref = dense_conv(wm, x, stride)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_bins", [1, 2, 4])
def test_sparse_conv2d_pattern_reorder_bit_identity(n_bins):
    """Degree-binned tap layouts produce bit-identical outputs to the
    unreordered layout — the epilogue gather relabels filters, each
    filter's tap accumulation order is untouched."""
    wm, mask = pattern_case(64, 32, connectivity=0.5, seed=3)
    plain = ops.pack_taps(wm, mask, reorder=False)
    reord = ops.pack_taps(wm, mask, reorder=True, n_bins=n_bins)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 9, 9, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (64,), jnp.float32)
    y0 = ops.sparse_conv2d_pattern(x, plain, kh=3, kw=3, stride=2, bias=b,
                                   act="relu")
    y1 = ops.sparse_conv2d_pattern(x, reord, kh=3, kw=3, stride=2, bias=b,
                                   act="relu")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert reord.L_effective <= plain.L_max


@pytest.mark.parametrize("P,Q,kh,kw,stride,conn", [
    (32, 16, 3, 3, 1, 0.0),      # pure 4-of-9 patterns
    (32, 16, 3, 3, 2, 0.5),      # patterns + connectivity, stride 2
    (64, 32, 5, 5, 2, 0.5),      # non-3x3: connectivity-only, stride 2
])
def test_implicit_tap_gather_parity(P, Q, kh, kw, stride, conn):
    """Implicit tap-gather (straight off the padded feature map — no
    patch tensor, no alive band) matches the materialized tap path within
    fp32 tolerance and the masked ``lax.conv`` oracle."""
    wm, mask = pattern_case(P, Q, kh, kw, connectivity=conn)
    tap = ops.pack_taps(wm, mask)
    assert tap.k_full is not None                 # pack-time implicit aux
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 11, 9, Q), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(7), (P,), jnp.float32)
    y_imp = ops.sparse_conv2d_pattern(x, tap, kh=kh, kw=kw, stride=stride,
                                      bias=b, act="relu", implicit=True)
    y_mat = ops.sparse_conv2d_pattern(x, tap, kh=kh, kw=kw, stride=stride,
                                      bias=b, act="relu", implicit=False)
    np.testing.assert_allclose(np.asarray(y_imp), np.asarray(y_mat),
                               rtol=1e-5, atol=1e-5)
    y_ref = jax.nn.relu(dense_conv(wm, x, stride) + b)
    np.testing.assert_allclose(np.asarray(y_imp), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_implicit_tap_gather_legacy_layout_without_k_full():
    """Layouts packed before the ``k_full`` aux existed still run
    implicit: ``bin_k_full`` reconstructs ``alive[t_idx]`` on the fly."""
    import dataclasses

    wm, mask = pattern_case(32, 16, connectivity=0.5)
    tap = ops.pack_taps(wm, mask, use_cache=False)
    legacy = dataclasses.replace(tap, k_full=None)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 10, 10, 16),
                          jnp.float32)
    y = ops.sparse_conv2d_pattern(x, tap, kh=3, kw=3, implicit=True)
    y_legacy = ops.sparse_conv2d_pattern(x, legacy, kh=3, kw=3,
                                         implicit=True)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_legacy))


def test_pack_taps_default_bins_shrink_connectivity_padding():
    """The raised default (8 bins) must price connectivity-bearing tap
    layouts at strictly less padding than the old 4-bin default — the
    ROADMAP measurement this PR locks in."""
    wm, mask = pattern_case(128, 64, connectivity=0.5, seed=9)
    b4 = ops.pack_taps(wm, mask, n_bins=4)
    b8 = ops.pack_taps(wm, mask)                  # default
    assert b8.n_bins == 8
    assert b8.padding_overhead < b4.padding_overhead
    # bit-identical outputs regardless of binning
    x = jax.random.normal(jax.random.PRNGKey(10), (1, 8, 8, 64), jnp.float32)
    y4 = ops.sparse_conv2d_pattern(x, b4, kh=3, kw=3)
    y8 = ops.sparse_conv2d_pattern(x, b8, kh=3, kw=3)
    np.testing.assert_array_equal(np.asarray(y4), np.asarray(y8))


def test_pack_taps_cache_key_separation():
    """A TapLayout and a PackedLayout of the same bytes never collide in
    the pack cache, and different tap knobs get distinct entries."""
    wm, mask = pattern_case(16, 8, connectivity=0.5)
    a = ops.pack_taps(wm, mask, n_bins=4)
    b = ops.pack_taps(wm, mask, n_bins=2)
    c = ops.pack_taps(wm, mask, n_bins=4)
    assert a is c and a is not b
    assert a.bin_degrees != b.bin_degrees or len(a.values) != len(b.values)


# -- compile_model: routing + whole-net parity -------------------------------

def _compiled_pattern_net(arch, seed=0):
    params = C.convnet_init(jax.random.PRNGKey(seed), arch,
                            dtype=jnp.float32)
    masks = RW.masks_for_spec(params, PATTERN_SPEC)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, PATTERN_SPEC)
    return pm, exec_params, report


@pytest.mark.parametrize("arch,expect_packed", [
    # every non-depthwise conv packs — including the 3-channel stem the
    # block producer cannot tile and the 1x1 / 5x5 connectivity layers
    (C.VGG_TINY, {"c1", "c2", "c3", "c4", "c5", "c6"}),
    (C.MOBILE_TINY, {"c1", "pw2", "pw3", "c4"}),
])
def test_pattern_net_packed_forward_parity(arch, expect_packed):
    pm, exec_params, report = _compiled_pattern_net(arch)
    packed = {r["path"].split("/")[0] for r in report if r["packed"]}
    assert packed == expect_packed, compiled_summary(report)
    assert all(r["kind"] == "pattern_conv" for r in report if r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(2), 4)
    y_ref = C.convnet_apply(pm, x, arch)
    y = C.convnet_apply(exec_params, x, arch)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_pattern_net_depthwise_still_skips():
    """§5.2.4: a pattern choice on a depthwise conv skips with the logged
    reason, never tap-lowers."""
    _, exec_params, report = _compiled_pattern_net(C.MOBILE_TINY)
    by_name = {r["path"].split("/")[0]: r for r in report}
    for dw_name in ("dw2", "dw3"):
        assert not by_name[dw_name]["packed"]
        assert "depthwise" in by_name[dw_name]["reason"]
        assert "packed" not in exec_params[dw_name]


def test_pattern_net_drop_dense():
    """keep_dense=False works for tap layouts: packed layers lose "w" and
    the net still runs through the tap-gather kernel."""
    params = C.convnet_init(jax.random.PRNGKey(0), C.VGG_TINY,
                            dtype=jnp.float32)
    masks = RW.masks_for_spec(params, PATTERN_SPEC)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(
        pm, masks, PATTERN_SPEC, spec=CompileSpec(keep_dense=False))
    for r in report:
        name = r["path"].split("/")[0]
        assert ("w" in exec_params[name]) == (not r["packed"])
    x, _ = C.synthetic_images(jax.random.PRNGKey(1), 2)
    y_ref = C.convnet_apply(pm, x, C.VGG_TINY)
    y = C.convnet_apply(exec_params, x, C.VGG_TINY)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_pattern_on_non_conv_weight_skips():
    """pattern mapped onto a 2-D FC weight must skip, not tap-lower."""
    params = {"fc": {"w": jnp.ones((64, 64), jnp.float32)}}
    _, report = compile_model(
        params, None, [(r"fc/w", RW.SchemeChoice("pattern"))])
    assert not report[0]["packed"]
    assert "conv weight" in report[0]["reason"]


# -- mapper regression: a pattern pick compiles sparse, not dense ------------

def test_mapper_pattern_pick_compiles_to_sparse_producer():
    """Remark 1 end to end: the rule mapper's hard-dataset pattern pick
    must reach the tap-gather producer — pre-PR it fell through
    compile_model as the logged 'no block scheme mapped' dense fallback."""
    arch_specs = [("c2", 16, 32, 64, 3, 3, False),
                  ("c3", 16, 64, 64, 3, 3, False)]
    layers = MR.conv_layers(arch_specs)
    spec, rep = MR.map_rules(layers, dataset_hard=True)
    assert all(r["scheme"] == "pattern" for r in rep)
    params = {
        "c2": {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32, 3, 3),
                                      jnp.float32) * 0.1},
        "c3": {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 64, 3, 3),
                                      jnp.float32) * 0.1},
    }
    masks = RW.masks_for_spec(params, spec)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, spec)
    assert all(r["packed"] for r in report), compiled_summary(report)
    assert all(r["kind"] == "pattern_conv" for r in report)
    assert all(r["flops_saved"] > 0.3 for r in report)
    from repro.core.packed import TapLayout
    assert isinstance(exec_params["c2"]["packed"], TapLayout)


def test_mapper_pattern_latency_uses_executed_cost():
    """The rule report prices a pattern pick at the executed-tap fraction
    (taps/9 x surviving kernels), not at the raw 4/9 density."""
    from repro.core.latency_model import matmul_latency, pattern_executed_frac
    convs = MR.conv_layers([("c1", 28, 64, 64, 3, 3, False)])
    _, rep = MR.map_rules(convs, dataset_hard=True)
    ld = convs[0]
    conn = 1 - 4 / 9
    frac = pattern_executed_frac(conn)
    want = matmul_latency(ld.M, ld.K, ld.N, scheme="pattern",
                          compression=1 / frac, executed_frac=frac)
    assert rep[0]["latency_s"] == pytest.approx(want)
    # executed cost is strictly below the raw-density pricing
    raw = matmul_latency(ld.M, ld.K, ld.N, scheme="pattern",
                         compression=9 / 4, executed_frac=4 / 9)
    assert rep[0]["latency_s"] < raw
