"""Int8 quantized value path end to end: ``core.quant`` -> kernel parity
vs the dequantized dense oracle on every kernel family, the CompileSpec
API (shim, digest, cache), scale-leaf validation, artifact version-skew
repack, and the mappers' per-layer precision picks."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcs as BCS
from repro.core import mapper_rule as MR
from repro.core import mapper_search as MS
from repro.core import quant as Q
from repro.core import regularity as R
from repro.core import reweighted as RW
from repro.core import validate as V
from repro.kernels import ops
from repro.serve import artifacts as ART
from repro.serve.compile import (CompileReport, CompileSpec, compile_model,
                                 compiled_summary, _pack_stacked,
                                 resolve_spec)

TOL = dict(rtol=1e-5, atol=1e-5)


def _block_mask(key, shape, block, keep=0.5):
    kb = jax.random.uniform(key, (shape[0] // block[0],
                                  shape[1] // block[1])) < keep
    return jnp.kron(kb.astype(jnp.float32), jnp.ones(block, jnp.float32))


def _fc_case(K=64, N=96, block=(16, 16), seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, N),
                          jnp.float32) * 0.1
    mask = _block_mask(jax.random.PRNGKey(seed + 100), (K, N), block)
    return w * mask, mask


def _conv_case(P=32, Q=16, kernel_block=(8, 8), seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, 3, 3),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, kernel_block, rate=0.5)
    return w * mask, mask


def _dense_conv_ref(x, dense_lowered, Q, P):
    kernel = jnp.asarray(dense_lowered).reshape(3, 3, Q, P)
    return jax.lax.conv_general_dilated(
        x, kernel, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# -- core.quant --------------------------------------------------------------

@pytest.mark.parametrize("gran", Q.GRANULARITIES)
def test_quantize_roundtrip_error_bound(gran):
    """to_dense of a quantized pack stays within the symmetric-scheme
    error bound (s/2 per element) of the float weights, and pruned
    positions stay exactly zero."""
    wm, mask = _fc_case()
    fp = ops.pack(wm, mask, (16, 16), reorder=True, use_cache=False)
    q8 = Q.quantize_layout(fp, scale_granularity=gran)
    assert q8.scales is not None and q8.value_dtype == "int8"
    assert all(np.asarray(v).dtype == np.int8 for v in q8.values)
    d_fp, d_q = fp.to_dense(), q8.to_dense()
    bound = float(np.abs(d_fp).max()) / Q.QMAX
    assert float(np.abs(d_fp - d_q).max()) <= bound
    np.testing.assert_array_equal(d_q[np.asarray(mask) == 0], 0.0)


def test_quantize_rejections():
    wm, mask = _fc_case(seed=1)
    fp = ops.pack(wm, mask, (16, 16), use_cache=False)
    q8 = Q.quantize_layout(fp)
    with pytest.raises(ValueError, match="already quantized"):
        Q.quantize_layout(q8)
    with pytest.raises(ValueError, match="value_dtype"):
        Q.quantize_layout(fp, value_dtype="int4")
    with pytest.raises(ValueError, match="scale_granularity"):
        Q.quantize_layout(fp, scale_granularity="tensor")
    with pytest.raises(TypeError, match="not a packable layout"):
        Q.quantize_layout(np.zeros((4, 4)))


# -- kernel parity vs the dequantized dense oracle ---------------------------

@pytest.mark.parametrize("gran", Q.GRANULARITIES)
def test_int8_parity_linear(gran):
    """bsr_matmul_packed dequantizes in-kernel: output == x @ to_dense."""
    wm, mask = _fc_case(seed=2)
    q8 = ops.pack(wm, mask, (16, 16), reorder=True, value_dtype="int8",
                  scale_granularity=gran)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, wm.shape[0]),
                          jnp.float32)
    y = ops.sparse_linear(x, packed=q8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ jnp.asarray(q8.to_dense())),
                               **TOL)


def test_int8_parity_moe_stacked():
    """The stacked (expert-batched) quantized pack serves through
    sparse_expert_linear with exactly the per-slice dequantized values."""
    E, K, N = 3, 32, 48
    w = jax.random.normal(jax.random.PRNGKey(4), (E, K, N),
                          jnp.float32) * 0.1
    mask = jnp.stack([_block_mask(jax.random.PRNGKey(40 + e), (K, N),
                                  (16, 16)) for e in range(E)])
    wm = w * mask
    stacked, _ = _pack_stacked(wm, mask, (16, 16), value_dtype="int8")
    assert stacked.scales is not None
    x = jax.random.normal(jax.random.PRNGKey(5), (E, 8, K), jnp.float32)
    y = ops.sparse_expert_linear(x, stacked)
    for e in range(E):
        ref = ops.pack(wm[e], mask[e], (16, 16), reorder=True,
                       value_dtype="int8").to_dense()
        np.testing.assert_allclose(np.asarray(y[e]),
                                   np.asarray(x[e] @ jnp.asarray(ref)),
                                   **TOL)


@pytest.mark.parametrize("implicit", [False, True])
def test_int8_parity_conv(implicit):
    """BCS conv kernels (materialized + implicit-GEMM) vs the dequantized
    dense conv."""
    wm, mask = _conv_case(seed=6)
    P, Q_, _, _ = wm.shape
    gemm_block, why = BCS.conv_gemm_block((8, 8), wm.shape)
    assert gemm_block is not None, why
    q8 = ops.pack(BCS.conv_lower(wm), BCS.conv_lower(mask), gemm_block,
                  reorder=True, conv=(3, 3, Q_), value_dtype="int8")
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 8, Q_), jnp.float32)
    y = ops.sparse_conv2d(x, q8, kh=3, kw=3, implicit=implicit)
    y_ref = _dense_conv_ref(x, q8.to_dense(), Q_, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


@pytest.mark.parametrize("implicit", [False, True])
@pytest.mark.parametrize("gran", Q.GRANULARITIES)
def test_int8_parity_tap(implicit, gran):
    """Tap-gather kernels (materialized + implicit) vs the dequantized
    dense conv, at both scale granularities."""
    w = jax.random.normal(jax.random.PRNGKey(8), (16, 12, 3, 3),
                          jnp.float32) * 0.1
    mask = R.pattern_mask(w, connectivity_rate=0.4)
    wm = w * mask
    q8 = ops.pack_taps(wm, mask, value_dtype="int8",
                       scale_granularity=gran)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 7, 7, 12), jnp.float32)
    y = ops.sparse_conv2d_pattern(x, q8, kh=3, kw=3, implicit=implicit)
    y_ref = _dense_conv_ref(x, q8.to_dense(), 12, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


# -- pack cache --------------------------------------------------------------

def test_pack_cache_fp_int8_no_collision():
    wm, mask = _fc_case(seed=10)
    fp = ops.pack(wm, mask, (16, 16), reorder=True)
    q8 = ops.pack(wm, mask, (16, 16), reorder=True, value_dtype="int8")
    assert q8 is not fp and q8.scales is not None and fp.scales is None
    assert ops.pack(wm, mask, (16, 16), reorder=True,
                    value_dtype="int8") is q8
    assert ops.pack(wm, mask, (16, 16), reorder=True) is fp


# -- validation --------------------------------------------------------------

def test_validate_accepts_quantized_layouts():
    wm, mask = _fc_case(seed=11)
    q8 = ops.pack(wm, mask, (16, 16), reorder=True, value_dtype="int8",
                  use_cache=False)
    assert V.validate_layout(q8) is q8
    w = jax.random.normal(jax.random.PRNGKey(12), (8, 8, 3, 3),
                          jnp.float32)
    mask = R.pattern_mask(w, connectivity_rate=0.25)
    tap = ops.pack_taps(w * mask, mask, value_dtype="int8",
                        scale_granularity="out", use_cache=False)
    assert V.validate_layout(tap) is tap


def test_validate_rejects_malformed_scales():
    wm, mask = _fc_case(seed=13)
    q8 = ops.pack(wm, mask, (16, 16), reorder=True, value_dtype="int8",
                  use_cache=False)
    fp = ops.pack(wm, mask, (16, 16), reorder=True, use_cache=False)
    cases = {
        "int values, no scales": dataclasses.replace(q8, scales=None),
        "scales on float values": dataclasses.replace(
            fp, scales=q8.scales),
        "bin count mismatch": dataclasses.replace(
            q8, scales=q8.scales + (q8.scales[0],)),
        "granularity shape": dataclasses.replace(
            q8, scales=tuple(s[..., None] for s in q8.scales)),
        "negative scale": dataclasses.replace(
            q8, scales=(jnp.full_like(q8.scales[0], -1.0),)
            + q8.scales[1:]),
    }
    for label, bad in cases.items():
        with pytest.raises(V.LayoutQuantError):
            V.validate_layout(bad)
        assert V.LayoutQuantError.code == "quant", label


# -- CompileSpec API ---------------------------------------------------------

def _lm_fixture(seed=0):
    K, N = 64, 96
    wm, mask = _fc_case(K, N, seed=seed)
    params = {"fc": {"w": wm}}
    masks = {"fc": {"w": mask}}
    mapping = [(r"fc/w", RW.SchemeChoice("block", (16, 16)))]
    return params, masks, mapping


def test_compile_spec_validation():
    with pytest.raises(ValueError, match="value_dtype"):
        CompileSpec(value_dtype="fp8")
    with pytest.raises(ValueError, match="scale_granularity"):
        CompileSpec(scale_granularity="tensor")
    with pytest.raises(ValueError, match="block_override"):
        CompileSpec(block_override=(16, 16, 16))
    spec = CompileSpec(exclude=["router"], n_bins=2.0)
    assert spec.exclude == ("router",) and spec.n_bins == 2
    assert CompileSpec.from_json(spec.to_json()) == spec


def test_resolve_spec_shim():
    """Legacy keywords still work (with a DeprecationWarning) and build
    the equivalent spec; mixing or misspelling them is a TypeError."""
    with pytest.warns(DeprecationWarning):
        assert resolve_spec(keep_dense=False) == CompileSpec(
            keep_dense=False)
    assert resolve_spec(None) == CompileSpec()       # no kwargs, no warning
    with pytest.raises(TypeError, match="not both"):
        resolve_spec(CompileSpec(), keep_dense=False)
    with pytest.raises(TypeError, match="unknown"):
        resolve_spec(keep_sparse=True)
    with pytest.raises(TypeError, match="CompileSpec"):
        resolve_spec({"keep_dense": False})


def test_compile_model_legacy_kwargs_warn_and_match_spec():
    params, masks, mapping = _lm_fixture(seed=20)
    with pytest.warns(DeprecationWarning):
        legacy, rep_l = compile_model(params, masks, mapping,
                                      keep_dense=False)
    fresh, rep_s = compile_model(params, masks, mapping,
                                 spec=CompileSpec(keep_dense=False))
    assert rep_l.spec == rep_s.spec
    assert "w" not in legacy["fc"] and "w" not in fresh["fc"]
    # same spec -> bit-identical pack either way
    np.testing.assert_array_equal(
        np.asarray(legacy["fc"]["packed"].values[0]),
        np.asarray(fresh["fc"]["packed"].values[0]))


def test_model_digest_spec_legacy_equivalence():
    params, masks, mapping = _lm_fixture(seed=21)
    by_spec = ART.model_digest(params, masks, mapping,
                               spec=CompileSpec(n_bins=2))
    assert by_spec == ART.model_digest(params, masks, mapping, n_bins=2)
    assert by_spec != ART.model_digest(params, masks, mapping)
    # serving-time-only knobs do not move the digest
    base = ART.model_digest(params, masks, mapping)
    assert base == ART.model_digest(params, masks, mapping,
                                    spec=CompileSpec(keep_dense=False))
    assert base == ART.model_digest(params, masks, mapping,
                                    spec=CompileSpec(implicit=True))
    # the precision knob does
    assert base != ART.model_digest(params, masks, mapping,
                                    spec=CompileSpec(value_dtype="int8"))


def test_compile_model_int8_end_to_end():
    params, masks, mapping = _lm_fixture(seed=22)
    exec_params, report = compile_model(
        params, masks, mapping, spec=CompileSpec(value_dtype="int8"))
    (row,) = report.packed
    assert row.value_dtype == "int8" and row["value_dtype"] == "int8"
    packed = exec_params["fc"]["packed"]
    assert packed.scales is not None
    x = jax.random.normal(jax.random.PRNGKey(23), (8, 64), jnp.float32)
    y = ops.sparse_linear(x, packed=packed)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ jnp.asarray(packed.to_dense())),
        **TOL)
    assert "values=int8" in compiled_summary(report)
    # report roundtrips through its manifest form, spec included
    back = CompileReport.from_json(json.loads(json.dumps(report.to_json())))
    assert back.spec == report.spec
    assert back[0].value_dtype == "int8"


def test_choice_precision_overrides_spec_default():
    params, masks, mapping = _lm_fixture(seed=24)
    mapping = [(pat, dataclasses.replace(c, value_dtype="int8"))
               for pat, c in mapping]
    exec_params, report = compile_model(params, masks, mapping)
    assert report[0].value_dtype == "int8"
    assert exec_params["fc"]["packed"].scales is not None


# -- artifacts ---------------------------------------------------------------

def test_artifact_roundtrip_preserves_scales(tmp_path):
    params, masks, mapping = _lm_fixture(seed=25)
    spec = CompileSpec(value_dtype="int8")
    exec_params, report = compile_model(params, masks, mapping, spec=spec,
                                        artifact_dir=tmp_path)
    key = ART.model_digest(params, masks, mapping, spec=spec)
    warm = ART.load_grafted(tmp_path, key, params)
    assert warm is not None
    warm_params, warm_report = warm
    assert warm_report.spec == spec
    assert warm_report[0].value_dtype == "int8"
    loaded = warm_params["fc"]["packed"]
    assert loaded.scales is not None
    np.testing.assert_array_equal(
        np.asarray(loaded.to_dense()),
        np.asarray(exec_params["fc"]["packed"].to_dense()))


def test_artifact_version_skew_repacks(tmp_path):
    """A FORMAT_VERSION 1 artifact (pre-quantization layout serialization)
    must not warm-start: the loader rejects it and compile_model repacks
    + republishes at the current version."""
    params, masks, mapping = _lm_fixture(seed=26)
    compile_model(params, masks, mapping, artifact_dir=tmp_path)
    key = ART.model_digest(params, masks, mapping)
    man_path = tmp_path / key / ART.MANIFEST_FILE
    manifest = json.loads(man_path.read_text())
    assert manifest["format_version"] == ART.FORMAT_VERSION == 2
    manifest["format_version"] = 1
    man_path.write_text(json.dumps(manifest))
    assert ART.load_grafted(tmp_path, key, params) is None
    with pytest.raises(ART.ArtifactVersionSkew):
        ART.load_artifact(tmp_path, key)
    exec_params, report = compile_model(params, masks, mapping,
                                        artifact_dir=tmp_path)
    assert report.packed           # fresh repack, not a graft of v1 data
    assert json.loads(man_path.read_text())["format_version"] == 2
    assert ops.sparse_linear(
        jnp.ones((2, 64), jnp.float32),
        packed=exec_params["fc"]["packed"]).shape == (2, 96)


# -- mapper precision picks --------------------------------------------------

def test_rule_mapper_picks_int8_when_memory_bound():
    """Decode-shaped FC (small M, big weight): the weight read dominates
    the roofline, so the re-priced int8 pick wins; a compute-bound layer
    keeps float values (no modeled win -> no free quantization error)."""
    decode = [MR.LayerDesc("dec/w", "fc", 256, 4096, 4096)]
    spec, report = MR.map_rules(decode)
    assert report[0]["scheme"] == "block"
    assert report[0]["value_dtype"] == "int8"
    assert spec[0][1].value_dtype == "int8"
    prefill = [MR.LayerDesc("pre/w", "fc", 65536, 4096, 4096)]
    _, report = MR.map_rules(prefill)
    assert report[0]["scheme"] == "block"
    assert report[0]["value_dtype"] is None


def test_search_precision_action_to_spec():
    layers = [MR.LayerDesc("a/w", "fc", 256, 4096, 4096),
              MR.LayerDesc("b/w", "fc", 256, 4096, 4096)]
    a_s = np.array([MS.SCHEME_MENU.index("block"),
                    MS.SCHEME_MENU.index("unstructured")])
    a_b = np.array([len(MS.BLOCK_MENU) - 1] * 2)
    a_p = np.array([1, 1])
    spec = MS.actions_to_spec(layers, a_s, a_b, a_p)
    assert spec[0][1].value_dtype == "int8"      # quantizable scheme
    assert spec[1][1].value_dtype is None        # inert on unstructured
    # legacy two-action callers still work (no precision picks)
    legacy = MS.actions_to_spec(layers, a_s, a_b)
    assert all(c.value_dtype is None for _, c in legacy)
    # int8 pricing never makes the modeled mapping slower
    t_fp = MS.mapping_latency(layers, a_s, a_b)
    t_q8 = MS.mapping_latency(layers, a_s, a_b, a_p)
    assert t_q8 < t_fp
