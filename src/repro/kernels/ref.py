"""Pure-jnp oracle for the BCS block-sparse matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_to_dense(values, k_idx, K):
    """(Nb, L, bk, bn) + (Nb, L) -> dense (K, N).  Scatter-ADD so the
    zero-padding slots (k_idx 0, zero values) are harmless."""
    Nb, L, bk, bn = values.shape
    Kb = K // bk
    dense_blocks = jnp.zeros((Kb, Nb, bk, bn), values.dtype)
    jj = jnp.broadcast_to(jnp.arange(Nb)[:, None], (Nb, L))
    dense_blocks = dense_blocks.at[k_idx.reshape(-1),
                                   jj.reshape(-1)].add(
        values.reshape(Nb * L, bk, bn))
    return dense_blocks.transpose(0, 2, 1, 3).reshape(K, Nb * bn)


def bsr_matmul_ref(x, values, k_idx, bias=None, act="none"):
    w = uniform_to_dense(values, k_idx, x.shape[1])
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act == "silu":
        y = y * jax.nn.sigmoid(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def masked_matmul_ref(x, w, mask, bias=None, act="none"):
    y = jnp.dot(x.astype(jnp.float32),
                (w * mask.astype(w.dtype)).astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if act == "silu":
        y = y * jax.nn.sigmoid(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
