"""Pallas TPU kernel: BCS block-sparse matmul  y = x @ W_sparse.

The TPU executor for the paper's compiler contribution (§4.3): the grid
iterates ONLY over surviving weight blocks — pruned blocks are never read
from HBM nor multiplied.  The block-column index array is scalar-prefetched
(SMEM) and drives the x BlockSpec index_map, the TPU analogue of
PatDNN-style sparsity-baked codegen.

Layout (from repro.core.bcs.pad_to_uniform_csc):
  values (Nb, L, bk, bn)  surviving blocks per output column, zero-padded
  k_idx  (Nb, L) int32    K-block index each slot reads from
Grid: (M/bm, Nb, L) — L innermost so the fp32 VMEM accumulator tile is
revisited; equal trip counts per (i, j) = the load-balance analogue of the
paper's row reordering.  Epilogue (bias + activation) fuses into the final
store (layer-fusion analogue, §A.1).

Accumulation is always fp32 (``preferred_element_type`` on the MXU dot +
fp32 VMEM scratch); bf16 inputs therefore take the mixed-precision path —
bf16 reads, fp32 accumulate, one rounding on the final store.

Ragged M is handled here: M is zero-padded up to the next ``bm`` multiple
before the grid launch and the pad rows are sliced off the output, so
callers never silently fall back to a dense matmul.

``bsr_matmul`` is the raw single-bin launch; consumers go through
``bsr_matmul_packed``, which takes a ``core.packed.PackedLayout`` — the
repo-wide interchange format — runs one launch per degree bin (row
reordering/binning: each bin is padded only to its own max column degree)
and gathers outputs back to original column order in the epilogue.

``tap_gather_conv`` (bottom of this file) is the second kernel: the
executor for pattern/connectivity-pruned convolutions, consuming the
``core.packed.TapLayout`` sibling format.  Where the BCS grid pays one
step per surviving BLOCK, per-kernel pattern masks have no block
structure, so that grid shape would cost one step per scalar tap; the tap
kernel instead keeps the alive im2col band VMEM-resident and gathers each
output filter's surviving taps in one (M tile, filter group) step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(k_idx, x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_l, act):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0, 0],
                            preferred_element_type=jnp.float32)

    @pl.when(l == n_l - 1)
    def _store():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[0].astype(jnp.float32)
        if act == "silu":
            out = out * jax.nn.sigmoid(out)
        elif act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _auto_interpret() -> bool:
    """Run the kernel body in interpret mode unless we are on real TPU."""
    return jax.default_backend() != "tpu"


def _m_tile(M, bm, dtype):
    """Pick the M tile: split M over the minimum number of bm-sized tiles,
    then shrink the tile to the aligned ceiling of the per-tile share so
    zero-padding stays under one alignment unit (M=129 with bm=128 runs
    2x72 rows, not 2x128).  Alignment is the Mosaic second-minor minimum:
    8 rows for f32, 16 for bf16; decode arrives with M = batch < both."""
    align = 8 if dtype == jnp.float32 else 16
    n_tiles = -(-M // bm) if M > bm else 1
    per_tile = -(-M // n_tiles)
    bm = min(bm, ((per_tile + align - 1) // align) * align)
    return bm, ((M + bm - 1) // bm) * bm


@functools.partial(jax.jit,
                   static_argnames=("bm", "act", "interpret", "out_dtype"))
def bsr_matmul(x, values, k_idx, bias=None, *, bm=128, act="none",
               interpret=None, out_dtype=None):
    """x (M, K) @ BCS-sparse W (K, N) -> (M, N).

    values (Nb, L, bk, bn); k_idx (Nb, L) int32.  ``interpret=None``
    auto-detects the backend (Pallas lowering on TPU, interpreter
    elsewhere).  ``out_dtype`` defaults to x.dtype; pass jnp.float32 to
    keep the fp32 accumulator precision on a bf16 input."""
    if interpret is None:
        interpret = _auto_interpret()
    M, K = x.shape
    Nb, L, bk, bn = values.shape
    N = Nb * bn
    bm, Mp = _m_tile(M, bm, x.dtype)
    assert K % bk == 0, (K, bk)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    out_dtype = out_dtype or x.dtype

    grid = (Mp // bm, Nb, L)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, l, kidx: (i, kidx[j, l])),
        pl.BlockSpec((1, 1, bk, bn), lambda i, j, l, kidx: (j, l, 0, 0)),
    ]
    args = [x, values]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l, kidx: (0, j)))
        args.append(bias.reshape(1, N))
        kern = functools.partial(_kernel, n_l=L, act=act)
    else:
        def kern(k_idx_ref, x_ref, w_ref, o_ref, acc_ref):
            _kernel(k_idx_ref, x_ref, w_ref, None, o_ref, acc_ref,
                    n_l=L, act=act)

    y = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, l, kidx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        interpret=interpret,
    )(k_idx, *args)
    return y[:M] if Mp != M else y


def bsr_matmul_packed(x, layout, bias=None, *, bm=128, act="none",
                      interpret=None, out_dtype=None):
    """x (M, K) @ PackedLayout W (K, N) -> (M, N).

    One ``bsr_matmul`` launch per degree bin — each bin's columns are padded
    only to the bin max, so a reordered layout executes
    ``layout.executed_blocks`` < Nb * L_max blocks.  Bias and activation
    fuse into each bin's epilogue (bias is gathered into layout column
    order first); the final column gather restores the original output
    order.  Per-column accumulation order is identical to the single-bin
    kernel, so reordered and unreordered results are bit-identical.
    """
    outs = []
    for vals_b, kidx_b, bias_b in zip(layout.values, layout.k_idx,
                                      layout.bin_bias(bias)):
        outs.append(bsr_matmul(x, vals_b, kidx_b, bias=bias_b, bm=bm,
                               act=act, interpret=interpret,
                               out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return layout.unpermute_cols(y)


# ---------------------------------------------------------------------------
# Tap-gather kernel: pattern/connectivity-pruned convs (PatDNN/PCONV style)
# ---------------------------------------------------------------------------

def _tap_kernel(t_idx, x_ref, w_ref, b_ref, o_ref, *, act):
    """One grid step per (M tile, filter group): gather this group's
    surviving taps from the VMEM-resident alive band and contract them in a
    single dot — no cross-step accumulator, epilogue fused into the same
    step."""
    j = pl.program_id(1)
    taps = t_idx[j]                                     # (L,) int32, SMEM
    g = jnp.take(x_ref[...], taps, axis=1)              # (bm, L)
    out = jnp.dot(g, w_ref[0], preferred_element_type=jnp.float32)
    if b_ref is not None:
        out = out + b_ref[0].astype(jnp.float32)
    if act == "silu":
        out = out * jax.nn.sigmoid(out)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "act", "interpret", "out_dtype"))
def tap_gather_conv(x, values, t_idx, bias=None, *, bm=128, act="none",
                    interpret=None, out_dtype=None):
    """x (M, R) alive im2col band @ per-group tap lists -> (M, G*group).

    The executor for pattern/connectivity-pruned convolutions (one launch
    per ``core.packed.TapLayout`` degree bin): ``values`` (G, L, group)
    holds each filter group's surviving-tap weights, ``t_idx`` (G, L) the
    band row each slot reads.  Where the BCS kernel's grid pays one step
    per (bk, bn) BLOCK — a full grid step per single tap at the (1, group)
    granularity pattern masks force — this kernel keeps the whole alive
    band (bm, R) resident in VMEM and gathers each group's taps inside ONE
    step, so the grid is (M/bm, G) regardless of tap count.  Pruned weight
    taps are never stored nor multiplied; band rows dead for every filter
    never reach the kernel at all (``TapLayout.alive`` excludes them from
    the host-side patch gather).  Padding slots read row 0 with zero
    values.  Bias + activation fuse into the same step (there is no
    cross-step accumulator to epilogue).

    The in-kernel gather runs on the VPU (per-filter tap sets defeat MXU
    tiling — the §5.2.4-style trade-off ``core.latency_model`` now prices);
    like ``bsr_matmul``, ``interpret=None`` auto-detects the backend and
    ragged M is padded here, never silently densified."""
    if interpret is None:
        interpret = _auto_interpret()
    M, R = x.shape
    G, L, gp = values.shape
    bm, Mp = _m_tile(M, bm, x.dtype)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    out_dtype = out_dtype or x.dtype
    N = G * gp

    grid = (Mp // bm, G)
    in_specs = [
        pl.BlockSpec((bm, R), lambda i, j, tidx: (i, 0)),
        pl.BlockSpec((1, L, gp), lambda i, j, tidx: (j, 0, 0)),
    ]
    args = [x, values]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, gp), lambda i, j, tidx: (0, j)))
        args.append(bias.reshape(1, N))
        kern = functools.partial(_tap_kernel, act=act)
    else:
        def kern(t_idx_ref, x_ref, w_ref, o_ref):
            _tap_kernel(t_idx_ref, x_ref, w_ref, None, o_ref, act=act)

    y = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, gp), lambda i, j, tidx: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        interpret=interpret,
    )(t_idx, *args)
    return y[:M] if Mp != M else y


def tap_gather_conv_packed(x, layout, bias=None, *, bm=128, act="none",
                           interpret=None, out_dtype=None):
    """x (M, R) alive band @ TapLayout -> (M, P), original filter order.

    One ``tap_gather_conv`` launch per degree bin (each bin padded only to
    its own max tap degree), outputs concatenated over bins and gathered
    back through ``inv_perm`` — the TapLayout mirror of
    ``bsr_matmul_packed``."""
    outs = []
    for vals_b, tidx_b, bias_b in zip(layout.values, layout.t_idx,
                                      layout.bin_bias(bias)):
        outs.append(tap_gather_conv(x, vals_b, tidx_b, bias=bias_b, bm=bm,
                                    act=act, interpret=interpret,
                                    out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return layout.unpermute_cols(y)
