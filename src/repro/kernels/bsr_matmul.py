"""Pallas TPU kernel: BCS block-sparse matmul  y = x @ W_sparse.

The TPU executor for the paper's compiler contribution (§4.3): the grid
iterates ONLY over surviving weight blocks — pruned blocks are never read
from HBM nor multiplied.  The block-column index array is scalar-prefetched
(SMEM) and drives the x BlockSpec index_map, the TPU analogue of
PatDNN-style sparsity-baked codegen.

Layout (from repro.core.bcs.pad_to_uniform_csc):
  values (Nb, L, bk, bn)  surviving blocks per output column, zero-padded
  k_idx  (Nb, L) int32    K-block index each slot reads from
Grid: (M/bm, Nb, L) — L innermost so the fp32 VMEM accumulator tile is
revisited; equal trip counts per (i, j) = the load-balance analogue of the
paper's row reordering.  Epilogue (bias + activation) fuses into the final
store (layer-fusion analogue, §A.1)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(k_idx, x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_l, act):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0, 0],
                            preferred_element_type=jnp.float32)

    @pl.when(l == n_l - 1)
    def _store():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[0].astype(jnp.float32)
        if act == "silu":
            out = out * jax.nn.sigmoid(out)
        elif act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "act", "interpret"))
def bsr_matmul(x, values, k_idx, bias=None, *, bm=128, act="none",
               interpret=True):
    """x (M, K) @ BCS-sparse W (K, N) -> (M, N).

    values (Nb, L, bk, bn); k_idx (Nb, L) int32.  interpret=True runs the
    kernel body on CPU (this container); on TPU pass interpret=False."""
    M, K = x.shape
    Nb, L, bk, bn = values.shape
    N = Nb * bn
    bm = min(bm, M)
    assert M % bm == 0 and K % bk == 0

    grid = (M // bm, Nb, L)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, l, kidx: (i, kidx[j, l])),
        pl.BlockSpec((1, 1, bk, bn), lambda i, j, l, kidx: (j, l, 0, 0)),
    ]
    args = [x, values]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l, kidx: (0, j)))
        args.append(bias.reshape(1, N))
        kern = functools.partial(_kernel, n_l=L, act=act)
    else:
        def kern(k_idx_ref, x_ref, w_ref, o_ref, acc_ref):
            _kernel(k_idx_ref, x_ref, w_ref, None, o_ref, acc_ref,
                    n_l=L, act=act)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, l, kidx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(k_idx, *args)
