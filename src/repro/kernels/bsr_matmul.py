"""Pallas TPU kernel: BCS block-sparse matmul  y = x @ W_sparse.

The TPU executor for the paper's compiler contribution (§4.3): the grid
iterates ONLY over surviving weight blocks — pruned blocks are never read
from HBM nor multiplied.  The block-column index array is scalar-prefetched
(SMEM) and drives the x BlockSpec index_map, the TPU analogue of
PatDNN-style sparsity-baked codegen.

Layout (from repro.core.bcs.pad_to_uniform_csc):
  values (Nb, L, bk, bn)  surviving blocks per output column, zero-padded
  k_idx  (Nb, L) int32    K-block index each slot reads from
Grid: (M/bm, Nb, L) — L innermost so the fp32 VMEM accumulator tile is
revisited; equal trip counts per (i, j) = the load-balance analogue of the
paper's row reordering.  Epilogue (bias + activation) fuses into the final
store (layer-fusion analogue, §A.1).

Accumulation is always fp32 (``preferred_element_type`` on the MXU dot +
fp32 VMEM scratch); bf16 inputs therefore take the mixed-precision path —
bf16 reads, fp32 accumulate, one rounding on the final store.

Ragged M is handled here: M is zero-padded up to the next ``bm`` multiple
before the grid launch and the pad rows are sliced off the output, so
callers never silently fall back to a dense matmul.

``bsr_matmul`` is the raw single-bin launch; consumers go through
``bsr_matmul_packed``, which takes a ``core.packed.PackedLayout`` — the
repo-wide interchange format — runs one launch per degree bin (row
reordering/binning: each bin is padded only to its own max column degree)
and gathers outputs back to original column order in the epilogue.

``tap_gather_conv`` (bottom of this file) is the second kernel: the
executor for pattern/connectivity-pruned convolutions, consuming the
``core.packed.TapLayout`` sibling format.  Where the BCS grid pays one
step per surviving BLOCK, per-kernel pattern masks have no block
structure, so that grid shape would cost one step per scalar tap; the tap
kernel instead keeps the alive im2col band VMEM-resident and gathers each
output filter's surviving taps in one (M tile, filter group) step.

``bsr_conv2d_implicit`` / ``tap_gather_conv_implicit`` are the
implicit-GEMM conv variants of both: instead of consuming a pre-extracted
``(B*Ho*Wo, Kh*Kw*C)`` patch matrix (a ~Kh*Kw-fold HBM blow-up of the
activations), the grid grows a batch dimension, the x BlockSpec index_map
selects the current image of the PADDED feature map (revisited across the
block/tap steps, so it is fetched once per image), and each step gathers
the rows it needs in-kernel from a tap -> (dy, dx, c) offset table riding
in SMEM — the patch tensor never exists in HBM.  Same fp32 accumulation,
degree-bin launches, and fused bias/act epilogues as the materialized
kernels, which stay as the parity oracle.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bcs as BCS


def _kernel(k_idx, x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *, n_l, act):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0, 0]
    if s_ref is not None:
        # int8 path: dequantize in-kernel (one fp32 scale per stored block
        # or per block column) BEFORE the dot, so accumulation stays fp32
        # and the result equals the dequantized dense reference
        w = w.astype(jnp.float32) * s_ref[0, 0]
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(l == n_l - 1)
    def _store():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[0].astype(jnp.float32)
        if act == "silu":
            out = out * jax.nn.sigmoid(out)
        elif act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _auto_interpret() -> bool:
    """Run the kernel body in interpret mode unless we are on real TPU.

    The ``PALLAS_INTERPRET`` env var overrides the auto-detection in both
    directions ("1"/"true" forces the interpreter, "0"/"false" forces real
    Mosaic lowering) so a TPU CI job can pin either mode explicitly."""
    env = os.environ.get("PALLAS_INTERPRET", "").strip().lower()
    if env:
        return env not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _same_pads(size, k, s):
    """XLA 'SAME' padding for one spatial dim: output ceil(size/s)."""
    out = -(-size // s)
    pad = max((out - 1) * s + k - size, 0)
    return pad // 2, pad - pad // 2


def conv_geometry(H, W, kh, kw, stride=1, padding="SAME"):
    """Conv output/padding geometry shared by ``kernels.ops.im2col`` and
    the implicit kernels: ((ph0, ph1), (pw0, pw1), Ho, Wo)."""
    if padding == "SAME":
        ph, pw = _same_pads(H, kh, stride), _same_pads(W, kw, stride)
    elif padding == "VALID":
        ph = pw = (0, 0)
    else:
        raise ValueError(padding)
    Ho = (H + ph[0] + ph[1] - kh) // stride + 1
    Wo = (W + pw[0] + pw[1] - kw) // stride + 1
    if Ho < 1 or Wo < 1:
        raise ValueError(
            f"kernel ({kh}, {kw}) does not fit the ({H}, {W}) feature map "
            f"under {padding} padding (output would be {Ho}x{Wo})")
    return ph, pw, Ho, Wo


def _m_tile(M, bm, dtype):
    """Pick the M tile: split M over the minimum number of bm-sized tiles,
    then shrink the tile to the aligned ceiling of the per-tile share so
    zero-padding stays under one alignment unit (M=129 with bm=128 runs
    2x72 rows, not 2x128).  Alignment is the Mosaic second-minor minimum:
    8 rows for f32, 16 for bf16; decode arrives with M = batch < both."""
    align = 8 if dtype == jnp.float32 else 16
    n_tiles = -(-M // bm) if M > bm else 1
    per_tile = -(-M // n_tiles)
    bm = min(bm, ((per_tile + align - 1) // align) * align)
    return bm, ((M + bm - 1) // bm) * bm


@functools.partial(jax.jit,
                   static_argnames=("bm", "act", "interpret", "out_dtype"))
def bsr_matmul(x, values, k_idx, bias=None, scales=None, *, bm=128,
               act="none", interpret=None, out_dtype=None):
    """x (M, K) @ BCS-sparse W (K, N) -> (M, N).

    values (Nb, L, bk, bn); k_idx (Nb, L) int32.  ``scales`` rides along
    for int8 values (``core.quant``): fp32, (Nb, L) per-block or (Nb,)
    per-block-column, dequantized in-kernel before the fp32-accumulated
    dot.  ``interpret=None`` auto-detects the backend (Pallas lowering on
    TPU, interpreter elsewhere).  ``out_dtype`` defaults to x.dtype; pass
    jnp.float32 to keep the fp32 accumulator precision on a bf16 input."""
    if interpret is None:
        interpret = _auto_interpret()
    M, K = x.shape
    Nb, L, bk, bn = values.shape
    N = Nb * bn
    bm, Mp = _m_tile(M, bm, x.dtype)
    assert K % bk == 0, (K, bk)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if out_dtype is None:
        out_dtype = x.dtype

    grid = (Mp // bm, Nb, L)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, l, kidx: (i, kidx[j, l])),
        pl.BlockSpec((1, 1, bk, bn), lambda i, j, l, kidx: (j, l, 0, 0)),
    ]
    args = [x, values]
    if scales is not None:
        sc = scales if scales.ndim == 2 else scales[:, None]
        # per-block scales index (j, l); per-column scales are constant
        # across the degree steps and index (j, 0)
        idx = ((lambda i, j, l, kidx: (j, l)) if sc.shape[1] == L
               else (lambda i, j, l, kidx: (j, 0)))
        in_specs.append(pl.BlockSpec((1, 1), idx))
        args.append(sc)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l, kidx: (0, j)))
        args.append(bias.reshape(1, N))
    has_s, has_b = scales is not None, bias is not None

    def kern(k_idx_ref, x_ref, w_ref, *rest):
        rest = list(rest)
        s_ref = rest.pop(0) if has_s else None
        b_ref = rest.pop(0) if has_b else None
        o_ref, acc_ref = rest
        _kernel(k_idx_ref, x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref,
                n_l=L, act=act)

    y = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, l, kidx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        interpret=interpret,
    )(k_idx, *args)
    return y[:M] if Mp != M else y


def bsr_matmul_packed(x, layout, bias=None, *, bm=128, act="none",
                      interpret=None, out_dtype=None):
    """x (M, K) @ PackedLayout W (K, N) -> (M, N).

    One ``bsr_matmul`` launch per degree bin — each bin's columns are padded
    only to the bin max, so a reordered layout executes
    ``layout.executed_blocks`` < Nb * L_max blocks.  Bias and activation
    fuse into each bin's epilogue (bias is gathered into layout column
    order first); the final column gather restores the original output
    order.  Per-column accumulation order is identical to the single-bin
    kernel, so reordered and unreordered results are bit-identical.
    Quantized layouts (int8 values, ``core.quant``) thread each bin's
    ``scales`` leaf into the launch for in-kernel dequantization.

    Tensor-parallel layouts (``layout.n_shards > 0``) dispatch to
    ``bsr_matmul_sharded`` — callers never need to care which they hold.
    """
    if layout.n_shards:
        return bsr_matmul_sharded(x, layout, bias=bias, bm=bm, act=act,
                                  interpret=interpret, out_dtype=out_dtype)
    outs = []
    for vals_b, kidx_b, sc_b, bias_b in zip(layout.values, layout.k_idx,
                                            layout.bin_scales(),
                                            layout.bin_bias(bias)):
        outs.append(bsr_matmul(x, vals_b, kidx_b, bias=bias_b, scales=sc_b,
                               bm=bm, act=act, interpret=interpret,
                               out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return layout.unpermute_cols(y)


def _sharded_launch(x, layout, bias, launch):
    """Shared shard-parallel driver: ``jax.vmap`` of the per-bin ``launch``
    over the leading shard axis of every per-bin leaf (values/indices/
    scales/bias), then ``layout.merge_shards`` — one gather that is both
    the cross-shard concat and the column un-reorder.  ``x`` is closed
    over (replicated to every shard).  When the leaves carry a
    ``NamedSharding`` over the mesh "model" axis, GSPMD partitions the
    vmapped launches into per-device kernels and turns the merge into the
    all-gather epilogue; on one device it is a plain batched launch —
    numerics are identical either way (per-column accumulation order is
    untouched, so sharded results are bit-identical to unsharded)."""
    operands = {"values": layout.values, "idx": layout.shard_index_leaves()}
    if layout.scales is not None:
        operands["scales"] = layout.scales
    if bias is not None:
        operands["bias"] = layout.bin_bias(bias)
    n_bins = layout.n_bins

    def shard_fn(op):
        outs = []
        for b in range(n_bins):
            outs.append(launch(
                x, op["values"][b], op["idx"][b],
                op["bias"][b] if "bias" in op else None,
                op["scales"][b] if "scales" in op else None))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)

    return layout.merge_shards(jax.vmap(shard_fn)(operands))


def bsr_matmul_sharded(x, layout, bias=None, *, bm=128, act="none",
                       interpret=None, out_dtype=None):
    """x (M, K) @ tensor-parallel PackedLayout (K, N) -> (M, N).

    Each shard runs the same per-bin ``bsr_matmul`` launches as
    ``bsr_matmul_packed`` over its own degree-balanced column slice
    (weights column-split, x replicated); outputs merge through the flat
    ``inv_perm`` gather.  See ``_sharded_launch`` for the vmap/GSPMD
    mechanics."""
    def launch(xx, vals, kidx, bias_b, sc_b):
        return bsr_matmul(xx, vals, kidx, bias=bias_b, scales=sc_b, bm=bm,
                          act=act, interpret=interpret, out_dtype=out_dtype)
    return _sharded_launch(x, layout, bias, launch)


# ---------------------------------------------------------------------------
# Tap-gather kernel: pattern/connectivity-pruned convs (PatDNN/PCONV style)
# ---------------------------------------------------------------------------

def _tap_kernel(t_idx, x_ref, w_ref, s_ref, b_ref, o_ref, *, act):
    """One grid step per (M tile, filter group): gather this group's
    surviving taps from the VMEM-resident alive band and contract them in a
    single dot — no cross-step accumulator, epilogue fused into the same
    step."""
    j = pl.program_id(1)
    taps = t_idx[j]                                     # (L,) int32, SMEM
    g = jnp.take(x_ref[...], taps, axis=1)              # (bm, L)
    w = w_ref[0]
    if s_ref is not None:
        # int8 path: per-slot scales arrive as (1, L), per-filter scales as
        # (1, 1, group) — dequantize before the dot (fp32 accumulation)
        s = s_ref[...]
        w = w.astype(jnp.float32) * (s[0][:, None] if s.ndim == 2
                                     else s[0, 0][None, :])
    out = jnp.dot(g, w, preferred_element_type=jnp.float32)
    if b_ref is not None:
        out = out + b_ref[0].astype(jnp.float32)
    if act == "silu":
        out = out * jax.nn.sigmoid(out)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "act", "interpret", "out_dtype"))
def tap_gather_conv(x, values, t_idx, bias=None, scales=None, *, bm=128,
                    act="none", interpret=None, out_dtype=None):
    """x (M, R) alive im2col band @ per-group tap lists -> (M, G*group).

    The executor for pattern/connectivity-pruned convolutions (one launch
    per ``core.packed.TapLayout`` degree bin): ``values`` (G, L, group)
    holds each filter group's surviving-tap weights, ``t_idx`` (G, L) the
    band row each slot reads.  Where the BCS kernel's grid pays one step
    per (bk, bn) BLOCK — a full grid step per single tap at the (1, group)
    granularity pattern masks force — this kernel keeps the whole alive
    band (bm, R) resident in VMEM and gathers each group's taps inside ONE
    step, so the grid is (M/bm, G) regardless of tap count.  Pruned weight
    taps are never stored nor multiplied; band rows dead for every filter
    never reach the kernel at all (``TapLayout.alive`` excludes them from
    the host-side patch gather).  Padding slots read row 0 with zero
    values.  Bias + activation fuse into the same step (there is no
    cross-step accumulator to epilogue).

    The in-kernel gather runs on the VPU (per-filter tap sets defeat MXU
    tiling — the §5.2.4-style trade-off ``core.latency_model`` now prices);
    like ``bsr_matmul``, ``interpret=None`` auto-detects the backend and
    ragged M is padded here, never silently densified."""
    if interpret is None:
        interpret = _auto_interpret()
    M, R = x.shape
    G, L, gp = values.shape
    bm, Mp = _m_tile(M, bm, x.dtype)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    out_dtype = out_dtype or x.dtype
    N = G * gp

    grid = (Mp // bm, G)
    in_specs = [
        pl.BlockSpec((bm, R), lambda i, j, tidx: (i, 0)),
        pl.BlockSpec((1, L, gp), lambda i, j, tidx: (j, 0, 0)),
    ]
    args = [x, values]
    if scales is not None:
        # per-slot (G, L) scales ride as a (1, L) row per group; per-filter
        # (G, 1, gp) scales as a (1, 1, gp) slab — rank picks the form
        if scales.ndim == 2:
            in_specs.append(pl.BlockSpec((1, L), lambda i, j, tidx: (j, 0)))
        else:
            in_specs.append(
                pl.BlockSpec((1, 1, gp), lambda i, j, tidx: (j, 0, 0)))
        args.append(scales)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, gp), lambda i, j, tidx: (0, j)))
        args.append(bias.reshape(1, N))
    has_s, has_b = scales is not None, bias is not None

    def kern(t_idx_ref, x_ref, w_ref, *rest):
        rest = list(rest)
        s_ref = rest.pop(0) if has_s else None
        b_ref = rest.pop(0) if has_b else None
        _tap_kernel(t_idx_ref, x_ref, w_ref, s_ref, b_ref, rest[0], act=act)

    y = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, gp), lambda i, j, tidx: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        interpret=interpret,
    )(t_idx, *args)
    return y[:M] if Mp != M else y


def tap_gather_conv_packed(x, layout, bias=None, *, bm=128, act="none",
                           interpret=None, out_dtype=None):
    """x (M, R) alive band @ TapLayout -> (M, P), original filter order.

    One ``tap_gather_conv`` launch per degree bin (each bin padded only to
    its own max tap degree), outputs concatenated over bins and gathered
    back through ``inv_perm`` — the TapLayout mirror of
    ``bsr_matmul_packed``, including the quantized-scales plumbing and the
    tensor-parallel dispatch (``layout.n_shards > 0`` routes to
    ``tap_gather_conv_sharded``)."""
    if layout.n_shards:
        return tap_gather_conv_sharded(x, layout, bias=bias, bm=bm, act=act,
                                       interpret=interpret,
                                       out_dtype=out_dtype)
    outs = []
    for vals_b, tidx_b, sc_b, bias_b in zip(layout.values, layout.t_idx,
                                            layout.bin_scales(),
                                            layout.bin_bias(bias)):
        outs.append(tap_gather_conv(x, vals_b, tidx_b, bias=bias_b,
                                    scales=sc_b, bm=bm, act=act,
                                    interpret=interpret,
                                    out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return layout.unpermute_cols(y)


def tap_gather_conv_sharded(x, layout, bias=None, *, bm=128, act="none",
                            interpret=None, out_dtype=None):
    """x (M, R) alive band @ tensor-parallel TapLayout -> (M, P).

    The tap mirror of ``bsr_matmul_sharded``: the alive band is GLOBAL
    (``layout.alive`` is replicated — every shard gathers from the same
    rows), each shard contracts its own degree-balanced filter groups, and
    ``merge_shards`` restores original filter order."""
    def launch(xx, vals, tidx, bias_b, sc_b):
        return tap_gather_conv(xx, vals, tidx, bias=bias_b, scales=sc_b,
                               bm=bm, act=act, interpret=interpret,
                               out_dtype=out_dtype)
    return _sharded_launch(x, layout, bias, launch)


# ---------------------------------------------------------------------------
# Implicit-GEMM conv kernels: im2col folded into the grid — the patch
# tensor (B*Ho*Wo, Kh*Kw*C) is never materialized in HBM.
# ---------------------------------------------------------------------------

def _out_positions(i, bm, geom):
    """In-kernel output-position decode for M tile ``i``: the (bm, 1)
    top-left input offsets (row index into the padded, flattened image) of
    this tile's output positions.  M-pad rows clamp to the last valid
    position — their gathers read a real pixel and are sliced off after the
    launch."""
    _, Wp, Ho, Wo, s = geom
    m = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    m = jnp.minimum(m, Ho * Wo - 1)
    return (m // Wo) * (s * Wp) + (m % Wo) * s


def _conv_kernel(tap_ref, x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *,
                 n_l, act, geom):
    """Implicit BCS conv step: the x tile (bm, bk) is gathered from the
    VMEM-resident padded image — slot (j, l)'s SMEM entry carries this
    K-block's (dy*Wp + dx, c0) offsets, so the gather lands on input
    channel slice [c0, c0+bk) at kernel tap (dy, dx) for each of the tile's
    bm output positions.  Accumulation/epilogue mirror ``_kernel``."""
    i, j, l = pl.program_id(1), pl.program_id(2), pl.program_id(3)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, _ = acc_ref.shape
    bk = w_ref.shape[2]
    C = x_ref.shape[2]
    rows = _out_positions(i, bm, geom) + tap_ref[j, l, 0]        # (bm, 1)
    cols = tap_ref[j, l, 1] + jax.lax.broadcasted_iota(jnp.int32, (bm, bk),
                                                       1)
    g = jnp.take(x_ref[...].reshape(-1), rows * C + cols, axis=0)
    w = w_ref[0, 0]
    if s_ref is not None:
        w = w.astype(jnp.float32) * s_ref[0, 0]
    acc_ref[...] += jnp.dot(g, w, preferred_element_type=jnp.float32)

    @pl.when(l == n_l - 1)
    def _store():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[0].astype(jnp.float32)
        if act == "silu":
            out = out * jax.nn.sigmoid(out)
        elif act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("geom", "bm", "act",
                                             "interpret", "out_dtype"))
def _conv_implicit_bin(xp, values, taps, bias=None, scales=None, *, geom,
                       bm=128, act="none", interpret=None, out_dtype=None):
    """One degree bin of the implicit BCS conv: xp (B, Hp*Wp, C) padded
    flattened images, values (Nb, L, bk, bn), taps (Nb, L, 2) int32 per-slot
    (dy*Wp + dx, c0) offsets (scalar-prefetched).  Grid (B, M/bm, Nb, L):
    the x BlockSpec pins the whole current image in VMEM (index depends on
    b only, so it is fetched once per image, not per block step) and each
    step gathers its (bm, bk) tile in-kernel — no patch tensor, no HBM
    re-read per block."""
    if interpret is None:
        interpret = _auto_interpret()
    Hp, Wp, Ho, Wo, _ = geom
    B, _, C = xp.shape
    Nb, L, bk, bn = values.shape
    N = Nb * bn
    bm, Mp = _m_tile(Ho * Wo, bm, xp.dtype)
    out_dtype = out_dtype or xp.dtype

    grid = (B, Mp // bm, Nb, L)
    in_specs = [
        pl.BlockSpec((1, Hp * Wp, C), lambda b, i, j, l, taps: (b, 0, 0)),
        pl.BlockSpec((1, 1, bk, bn), lambda b, i, j, l, taps: (j, l, 0, 0)),
    ]
    args = [xp, values]
    if scales is not None:
        sc = scales if scales.ndim == 2 else scales[:, None]
        idx = ((lambda b, i, j, l, taps: (j, l)) if sc.shape[1] == L
               else (lambda b, i, j, l, taps: (j, 0)))
        in_specs.append(pl.BlockSpec((1, 1), idx))
        args.append(sc)
    if bias is not None:
        in_specs.append(
            pl.BlockSpec((1, bn), lambda b, i, j, l, taps: (0, j)))
        args.append(bias.reshape(1, N))
    has_s, has_b = scales is not None, bias is not None

    def kern(tap_ref, x_ref, w_ref, *rest):
        rest = list(rest)
        s_ref = rest.pop(0) if has_s else None
        b_ref = rest.pop(0) if has_b else None
        o_ref, acc_ref = rest
        _conv_kernel(tap_ref, x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref,
                     n_l=L, act=act, geom=geom)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda b, i, j, l, taps: (b, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Mp, N), out_dtype),
        interpret=interpret,
    )(taps, *args)


def bsr_conv2d_implicit(x, layout, *, kh, kw, stride=1, padding="SAME",
                        bias=None, bm=128, act="none", interpret=None,
                        out_dtype=None):
    """x (B, H, W, C) * im2col-lowered PackedLayout -> (B, Ho, Wo, N),
    without ever materializing the patch tensor.

    The implicit mirror of ``bsr_matmul_packed`` over extracted patches:
    one ``_conv_implicit_bin`` launch per degree bin, bias + activation
    fused per bin, outputs gathered back to original filter order.  HBM
    holds only the zero-padded feature map (the halo copy, ~activation
    sized) instead of the Kh*Kw-fold patch blow-up; the kernel derives each
    K-block's input offsets from the layout's static ``conv_taps`` table
    (``core.bcs.conv_tap_table``, attached at pack time — derived on the
    fly for layouts packed without it).  Bit-identical to the materialized
    path: the gathered tiles equal the im2col rows, and per-column
    accumulation order is untouched."""
    B, H, W, C = x.shape
    assert layout.shape[0] == kh * kw * C, (
        f"layout K={layout.shape[0]} != kh*kw*Cin={kh * kw * C}")
    taps = layout.conv_taps or BCS.conv_tap_table(kh, kw, C,
                                                  layout.block[0])
    ph, pw, Ho, Wo = conv_geometry(H, W, kh, kw, stride, padding)
    Hp, Wp = H + ph[0] + ph[1], W + pw[0] + pw[1]
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0))).reshape(B, Hp * Wp, C)
    off_t = jnp.asarray([dy * Wp + dx for dy, dx, _ in taps], jnp.int32)
    c0_t = jnp.asarray([c0 for _, _, c0 in taps], jnp.int32)
    geom = (Hp, Wp, Ho, Wo, stride)
    outs = []
    for vals_b, kidx_b, sc_b, bias_b in zip(layout.values, layout.k_idx,
                                            layout.bin_scales(),
                                            layout.bin_bias(bias)):
        slot = jnp.stack([jnp.take(off_t, kidx_b),
                          jnp.take(c0_t, kidx_b)], axis=-1)
        outs.append(_conv_implicit_bin(xp, vals_b, slot, bias=bias_b,
                                       scales=sc_b, geom=geom, bm=bm,
                                       act=act, interpret=interpret,
                                       out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    y = layout.unpermute_cols(y)
    return y[:, :Ho * Wo].reshape(B, Ho, Wo, y.shape[-1])


def _tap_conv_kernel(tap_ref, x_ref, w_ref, s_ref, b_ref, o_ref, *, act,
                     geom):
    """Implicit tap-gather step: like ``_tap_kernel`` but the (bm, L) tap
    matrix is gathered straight from the VMEM-resident padded image —
    group j's SMEM row carries each tap slot's (dy*Wp + dx, c) offsets, so
    the alive im2col band is never built on the host either."""
    i, j = pl.program_id(1), pl.program_id(2)
    bm = o_ref.shape[1]
    C = x_ref.shape[2]
    base = _out_positions(i, bm, geom)                           # (bm, 1)
    flat = (base + tap_ref[j, :, 0][None, :]) * C + tap_ref[j, :, 1][None, :]
    g = jnp.take(x_ref[...].reshape(-1), flat, axis=0)           # (bm, L)
    w = w_ref[0]
    if s_ref is not None:
        s = s_ref[...]
        w = w.astype(jnp.float32) * (s[0][:, None] if s.ndim == 2
                                     else s[0, 0][None, :])
    out = jnp.dot(g, w, preferred_element_type=jnp.float32)
    if b_ref is not None:
        out = out + b_ref[0].astype(jnp.float32)
    if act == "silu":
        out = out * jax.nn.sigmoid(out)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("geom", "bm", "act",
                                             "interpret", "out_dtype"))
def _tap_implicit_bin(xp, values, taps, bias=None, scales=None, *, geom,
                      bm=128, act="none", interpret=None, out_dtype=None):
    """One degree bin of the implicit tap-gather conv: xp (B, Hp*Wp, C),
    values (G, L, group), taps (G, L, 2) int32 per-slot (dy*Wp + dx, c)
    offsets.  Grid (B, M/bm, G), no cross-step accumulator — epilogue fused
    into the single step, exactly like ``tap_gather_conv``."""
    if interpret is None:
        interpret = _auto_interpret()
    Hp, Wp, Ho, Wo, _ = geom
    B, _, C = xp.shape
    G, L, gp = values.shape
    N = G * gp
    bm, Mp = _m_tile(Ho * Wo, bm, xp.dtype)
    out_dtype = out_dtype or xp.dtype

    grid = (B, Mp // bm, G)
    in_specs = [
        pl.BlockSpec((1, Hp * Wp, C), lambda b, i, j, taps: (b, 0, 0)),
        pl.BlockSpec((1, L, gp), lambda b, i, j, taps: (j, 0, 0)),
    ]
    args = [xp, values]
    if scales is not None:
        if scales.ndim == 2:
            in_specs.append(
                pl.BlockSpec((1, L), lambda b, i, j, taps: (j, 0)))
        else:
            in_specs.append(
                pl.BlockSpec((1, 1, gp), lambda b, i, j, taps: (j, 0, 0)))
        args.append(scales)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, gp), lambda b, i, j, taps: (0, j)))
        args.append(bias.reshape(1, N))
    has_s, has_b = scales is not None, bias is not None

    def kern(tap_ref, x_ref, w_ref, *rest):
        rest = list(rest)
        s_ref = rest.pop(0) if has_s else None
        b_ref = rest.pop(0) if has_b else None
        _tap_conv_kernel(tap_ref, x_ref, w_ref, s_ref, b_ref, rest[0],
                         act=act, geom=geom)

    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, gp),
                                   lambda b, i, j, taps: (b, i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, Mp, N), out_dtype),
        interpret=interpret,
    )(taps, *args)


def tap_gather_conv_implicit(x, layout, *, kh, kw, stride=1, padding="SAME",
                             bias=None, bm=128, act="none", interpret=None,
                             out_dtype=None):
    """x (B, H, W, C) * TapLayout -> (B, Ho, Wo, P) implicit tap-gather:
    neither the patch tensor NOR the alive band is materialized in HBM.

    The implicit mirror of ``tap_gather_conv_packed``: one launch per
    degree bin, each filter group gathering its surviving taps straight
    from the padded feature map via the layout's ``k_full`` full-band row
    ids (``alive[t_idx]``, precomputed at pack time by
    ``core.bcs.pattern_lower``; reconstructed on the fly for legacy
    layouts).  Padding slots point at alive[0] with zero values, so they
    gather a real pixel and contribute nothing."""
    B, H, W, C = x.shape
    assert layout.shape[0] == kh * kw * C, (
        f"layout K={layout.shape[0]} != kh*kw*Cin={kh * kw * C}")
    ph, pw, Ho, Wo = conv_geometry(H, W, kh, kw, stride, padding)
    Hp, Wp = H + ph[0] + ph[1], W + pw[0] + pw[1]
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0))).reshape(B, Hp * Wp, C)
    geom = (Hp, Wp, Ho, Wo, stride)
    outs = []
    for vals_b, kf_b, sc_b, bias_b in zip(layout.values,
                                          layout.bin_k_full(),
                                          layout.bin_scales(),
                                          layout.bin_bias(bias)):
        t = kf_b // C
        slot = jnp.stack([(t // kw) * Wp + t % kw, kf_b % C],
                         axis=-1).astype(jnp.int32)
        outs.append(_tap_implicit_bin(xp, vals_b, slot, bias=bias_b,
                                      scales=sc_b, geom=geom, bm=bm,
                                      act=act, interpret=interpret,
                                      out_dtype=out_dtype))
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    y = layout.unpermute_cols(y)
    return y[:, :Ho * Wo].reshape(B, Ho, Wo, y.shape[-1])
