"""Jit'd dispatch layer over the sparse executor paths.

``sparse_linear`` picks the execution strategy the compiler framework
would emit for a pruned layer:
  PackedLayout         -> Pallas bsr_matmul (skips pruned blocks; ragged
                          M is zero-padded inside the kernel wrapper, so
                          the packed path never falls back to dense)
  dense weight (+mask) -> masked-dense matmul (mask fused by XLA)

``sparse_expert_linear`` is the batched variant for MoE expert stacks: a
``jax.vmap`` of the packed kernel over the leading expert axis, so the
three expert GEMMs (gate/up/down) execute through the same sparse path as
every other projection.

``sparse_conv2d`` is the CONV consumer: im2col patch extraction (tap-major
(kh, kw, q) feature order, matching ``core.bcs.conv_lower``) flattens the
convolution to one GEMM that dispatches through the same
``bsr_matmul_packed`` — block-punched conv masks (paper §4.1.2) become
whole dead BCS blocks, so pruned taps are skipped, not multiplied by zero.
Stride/padding are handled in the patch extraction; bias + activation fuse
into the kernel epilogue exactly as for ``sparse_linear``.

``sparse_conv2d_pattern`` is the pattern/connectivity CONV consumer: the
same im2col patch extraction, restricted to the layout's ``alive`` band,
then the Pallas tap-gather kernel (``bsr_matmul.tap_gather_conv``) — each
output filter multiplies ONLY its surviving taps, so 4-of-9 pattern masks
and connectivity-pruned kernels execute sparsely instead of falling back
to masked-dense.

Both conv consumers take ``implicit=`` (default None = auto): the implicit
mode skips the patch extraction entirely and runs the implicit-GEMM
kernels (``bsr_conv2d_implicit`` / ``tap_gather_conv_implicit``), which
gather input rows inside the kernel — the ``B*Ho*Wo*Kh*Kw*C`` patch tensor
never exists in HBM.  Auto-selection is by patch-tensor size: implicit
when the patch would be a real blow-up (kh*kw > 1) at least
``_IMPLICIT_MIN_PATCH_BYTES`` big (and, for the BCS path, the packing
block never straddles kernel taps, i.e. bk | Cin).  The materialized path
stays the parity oracle — the two are bit-identical for the BCS path and
fp32-close for taps.

``pack`` / ``pack_taps`` are the host-side codegen steps: they convert a
pruned weight into a ``core.packed.PackedLayout`` (block schemes) or
``core.packed.TapLayout`` (pattern schemes) — the two interchange formats
every sparse consumer shares — optionally degree-sorted/binned
(``reorder``) so the padded column/tap degree L drops toward the mean.

Cache-key contract: results are memoized on a blake2b content digest of
(layout kind, w bytes, mask bytes, w shape+dtype, block-or-group, reorder,
n_bins, quantization spec).  ``pack``/``pack_taps`` take ``value_dtype``
("int8") + ``scale_granularity`` to emit quantized layouts
(``core.quant``): the float pack is produced (or fetched) first — so a
quantized pack warms/reuses the float entry — then quantized and cached
under its own key.  Every knob that changes the produced layout is part of
the key,
so reordered and unreordered packs, different bin counts, block shapes, or
tap-group sizes of the SAME weights can never collide; entries are evicted
LRU under both a count and a byte bound (configurable via
``configure_pack_cache`` / REPRO_PACK_CACHE_MAX{,_BYTES}, every eviction
logged, occupancy + hit/miss counters in ``pack_cache_stats``).  Cached
layouts are frozen — the same instance is handed to every caller."""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import os
from collections import OrderedDict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import quant as QUANT
from repro.core.packed import PackedLayout
from repro.kernels.bsr_matmul import (bsr_conv2d_implicit, bsr_matmul_packed,
                                      conv_geometry, tap_gather_conv_implicit,
                                      tap_gather_conv_packed)
from repro.kernels import ref

# auto-selection floor for the implicit-GEMM conv mode: below this the
# patch tensor is too small for its HBM blow-up to matter and the
# materialized path's plain strided slices win on launch simplicity
_IMPLICIT_MIN_PATCH_BYTES = 1 << 20
# auto-selection ceiling: the implicit kernels pin one whole padded image
# in VMEM (x BlockSpec (1, Hp*Wp, C)), so auto never picks them when that
# block would not comfortably fit the ~16 MiB of a v5e core — explicit
# implicit=True can still force it (e.g. in interpret mode)
_IMPLICIT_MAX_IMAGE_BYTES = 8 << 20

_log = logging.getLogger("repro.kernels.ops")

_PACK_CACHE: OrderedDict = OrderedDict()
# entry cap and byte bound (values + k_idx + nnz), evicted LRU: a
# count-only bound would happily pin GBs of packed multi-MB projections
# for the process lifetime, and an unbounded cache in a long-lived serving
# process sweeping many layouts grows without bound.  Configurable via
# ``configure_pack_cache`` or the REPRO_PACK_CACHE_MAX{,_BYTES} env vars;
# every eviction is logged.
_PACK_CACHE_MAX = int(os.environ.get("REPRO_PACK_CACHE_MAX", "256"))
_PACK_CACHE_MAX_BYTES = int(
    os.environ.get("REPRO_PACK_CACHE_MAX_BYTES", str(256 << 20)))
_PACK_CACHE_BYTES = 0
_PACK_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _entry_bytes(layout: PackedLayout) -> int:
    leaves = jax.tree_util.tree_leaves(layout)
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in leaves)


def configure_pack_cache(max_entries=None, max_bytes=None) -> dict:
    """Set the pack-cache bounds (None keeps the current value), evicting
    down immediately if the new bounds are tighter.  Returns the active
    config merged with ``pack_cache_stats()``."""
    global _PACK_CACHE_MAX, _PACK_CACHE_MAX_BYTES
    if max_entries is not None:
        _PACK_CACHE_MAX = max(1, int(max_entries))
    if max_bytes is not None:
        _PACK_CACHE_MAX_BYTES = max(1, int(max_bytes))
    _evict_to_bounds()
    return {"max_entries": _PACK_CACHE_MAX,
            "max_bytes": _PACK_CACHE_MAX_BYTES, **pack_cache_stats()}


def pack_cache_stats() -> dict:
    """Current occupancy + lifetime hit/miss/eviction counters."""
    return {"entries": len(_PACK_CACHE), "bytes": _PACK_CACHE_BYTES,
            **_PACK_CACHE_STATS}


def _evict_to_bounds():
    """Evict LRU entries past the bounds, logging each (a serving process
    that evicts constantly needs a bigger cache — the log is the signal)."""
    global _PACK_CACHE_BYTES
    while (len(_PACK_CACHE) > _PACK_CACHE_MAX
           or _PACK_CACHE_BYTES > _PACK_CACHE_MAX_BYTES) \
            and len(_PACK_CACHE) > 1:
        key, evicted = _PACK_CACHE.popitem(last=False)
        eb = _entry_bytes(evicted)
        _PACK_CACHE_BYTES -= eb
        _PACK_CACHE_STATS["evictions"] += 1
        _log.info(
            "pack cache evict %s... (%.1f KiB) -> %d entr%s / %.1f MiB "
            "held (caps: %d entries / %.0f MiB)", key[:12], eb / 1024,
            len(_PACK_CACHE), "y" if len(_PACK_CACHE) == 1 else "ies",
            _PACK_CACHE_BYTES / 2**20, _PACK_CACHE_MAX,
            _PACK_CACHE_MAX_BYTES / 2**20)


def _digest(w: np.ndarray, mask: np.ndarray, block, reorder, n_bins,
            kind="bcs", conv=None, quant=None, n_shards=0) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str((kind, w.shape, str(w.dtype), block, bool(reorder),
                  int(n_bins), conv, quant, int(n_shards))).encode())
    h.update(np.ascontiguousarray(w).tobytes())
    h.update(np.ascontiguousarray(mask).tobytes())
    return h.hexdigest()


def _cache_put(key, out):
    """Insert a packed layout, then evict LRU entries past the bounds."""
    global _PACK_CACHE_BYTES
    _PACK_CACHE[key] = out
    _PACK_CACHE_BYTES += _entry_bytes(out)
    _PACK_CACHE_STATS["misses"] += 1
    _evict_to_bounds()


def _quant_spec(value_dtype, scale_granularity):
    """Normalize the (value_dtype, scale_granularity) pair for the cache
    digest: None (float pack) or a ('int8', granularity) tuple."""
    if value_dtype is None:
        return None
    return (str(value_dtype), str(scale_granularity))


def pack(w, mask, block=(128, 128), *, reorder=False, n_bins=4, conv=None,
         value_dtype=None, scale_granularity="block",
         n_shards=0, use_cache=True) -> PackedLayout:
    """Host-side packing of a pruned weight into the kernel layout.

    Returns a ``PackedLayout``.  With ``reorder`` the block columns are
    degree-sorted and split into ``n_bins`` bins (see
    ``core.bcs.pack_csc_reordered``); without it the layout is a single bin
    in original column order, bit-identical to the historical uniform CSC
    arrays.  ``conv=(kh, kw, cin)`` marks an im2col-lowered conv weight:
    the static K-block -> (dy, dx, c0) offset table
    (``core.bcs.conv_tap_table``) is attached as ``conv_taps`` aux so the
    implicit-GEMM kernel can gather from the feature map directly; the
    geometry is part of the cache digest.  ``value_dtype="int8"`` quantizes
    the packed values symmetrically (``core.quant``) at
    ``scale_granularity`` ("block" or "out"), attaching the fp32 scale
    leaves — the float pack is produced (and cached) first, then quantized.
    ``n_shards > 0`` emits the tensor-parallel layout (degree-balanced
    column shards, see ``core.bcs.shard_columns``); sharding implies the
    degree-sorted producer regardless of ``reorder``, and the shard count
    is part of the cache digest.
    """
    w = np.asarray(w)
    mask = np.asarray(mask)
    qspec = _quant_spec(value_dtype, scale_granularity)
    key = (_digest(w, mask, tuple(block), reorder, n_bins, conv=conv,
                   quant=qspec, n_shards=n_shards)
           if use_cache else None)
    if key is not None and key in _PACK_CACHE:
        _PACK_CACHE.move_to_end(key)
        _PACK_CACHE_STATS["hits"] += 1
        return _PACK_CACHE[key]
    if value_dtype is not None:
        base = pack(w, mask, block, reorder=reorder, n_bins=n_bins,
                    conv=conv, n_shards=n_shards, use_cache=use_cache)
        out = QUANT.quantize_layout(base, value_dtype=value_dtype,
                                    scale_granularity=scale_granularity)
    elif n_shards:
        out = BCS.pack_csc_reordered(w, mask, block, n_bins=n_bins,
                                     n_shards=n_shards)
    elif reorder:
        out = BCS.pack_csc_reordered(w, mask, block, n_bins=n_bins)
    else:
        values, k_idx, nnz, _ = BCS.pack_csc(w, mask, block)
        out = PackedLayout(values=(values,), k_idx=(k_idx,), nnz=nnz,
                           block=tuple(block), shape=tuple(w.shape))
    if conv is not None and out.conv_taps is None:
        kh, kw, cin = conv
        out = dataclasses.replace(
            out, conv_taps=BCS.conv_tap_table(kh, kw, cin, block[0]))
    if key is not None:
        _cache_put(key, out)
    return out


def pack_taps(w, mask, *, group=1, reorder=True, n_bins=8,
              value_dtype=None, scale_granularity="block",
              n_shards=0, use_cache=True):
    """Host-side packing of a pattern/connectivity-pruned conv weight into
    the tap-gather layout.

    Returns a ``core.packed.TapLayout`` (see ``core.bcs.pattern_lower``):
    per-output-filter tap lists over the im2col band, degree-sorted into
    ``n_bins`` bins when ``reorder`` is set.  The default is 8 bins — on
    connectivity-bearing tap layouts the per-filter degrees spread widely,
    and the ROADMAP measurement shows 8 equal-size bins recover ~89% of
    the 1-bin -> ideal padding gap where 4 recover ~75% (pure pattern
    layouts have uniform degrees, so extra bins cost nothing).  Shares the
    pack cache (and its cache-key contract — the layout kind is part of
    the digest, so a TapLayout and a PackedLayout of the same weights
    never collide).  ``value_dtype="int8"`` quantizes the tap values
    (``core.quant``); prefer ``scale_granularity="out"`` for group=1
    layouts, where a per-slot scale would cost 4 bytes per stored value.
    ``n_shards > 0`` emits the tensor-parallel TapLayout (degree-balanced
    filter-group shards; implies ``reorder``)."""
    w = np.asarray(w)
    mask = np.asarray(mask)
    qspec = _quant_spec(value_dtype, scale_granularity)
    key = (_digest(w, mask, (1, int(group)), reorder, n_bins, kind="taps",
                   quant=qspec, n_shards=n_shards)
           if use_cache else None)
    if key is not None and key in _PACK_CACHE:
        _PACK_CACHE.move_to_end(key)
        _PACK_CACHE_STATS["hits"] += 1
        return _PACK_CACHE[key]
    if value_dtype is not None:
        base = pack_taps(w, mask, group=group, reorder=reorder,
                         n_bins=n_bins, n_shards=n_shards,
                         use_cache=use_cache)
        out = QUANT.quantize_layout(base, value_dtype=value_dtype,
                                    scale_granularity=scale_granularity)
    else:
        out = BCS.pattern_lower(w, mask, group=group, n_bins=n_bins,
                                reorder=reorder or bool(n_shards),
                                n_shards=n_shards)
    if key is not None:
        _cache_put(key, out)
    return out


def clear_pack_cache():
    """Drop every memoized layout (test isolation / memory pressure)."""
    global _PACK_CACHE_BYTES
    _PACK_CACHE.clear()
    _PACK_CACHE_BYTES = 0


def sparse_linear(x, packed: PackedLayout | None = None, w=None, mask=None,
                  bias=None, act="none", bm=128, interpret=None):
    """x (..., K) -> (..., N) through whichever path applies.

    With ``packed`` (a PackedLayout) the Pallas BCS kernel always runs —
    one launch per degree bin, outputs gathered back to original column
    order (ragged leading dims are flattened; ragged M is padded inside
    ``bsr_matmul``).  ``interpret=None`` auto-detects the backend."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    if packed is not None:
        y = bsr_matmul_packed(x2, packed, bias=bias, bm=bm, act=act,
                              interpret=interpret)
    else:
        y = ref.masked_matmul_ref(
            x2, w, mask if mask is not None else jnp.ones_like(w),
            bias=bias, act=act)
    return y.reshape(*lead, y.shape[-1])


def im2col(x, kh, kw, stride=1, padding="SAME"):
    """x (B, H, W, C) -> patches (B, Ho, Wo, kh*kw*C).

    Feature order is tap-major, channel-minor — feature r = (i*kw + j)*C + c
    reads input channel c at kernel tap (i, j) — the exact row order of
    ``core.bcs.conv_lower``, so ``patches.reshape(-1, kh*kw*C) @ lowered_w``
    is the convolution.  The taps are a tiny unrolled loop (<= kh*kw slices)
    over one padded copy; XLA fuses the strided slices.  This is the
    MATERIALIZED path — it allocates the full ``B*Ho*Wo*kh*kw*C`` patch
    tensor; the implicit kernels fold this gather into their grid instead."""
    B, H, W, C = x.shape
    ph, pw, Ho, Wo = conv_geometry(H, W, kh, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    taps = [xp[:, i:i + stride * (Ho - 1) + 1:stride,
               j:j + stride * (Wo - 1) + 1:stride, :]
            for i in range(kh) for j in range(kw)]
    return jnp.concatenate(taps, axis=-1) if len(taps) > 1 else taps[0]


def patch_bytes(x, kh, kw, stride=1, padding="SAME"):
    """HBM bytes the MATERIALIZED im2col path allocates for its patch
    tensor — what the implicit mode avoids (and what auto-selection and
    the benches' peak-memory accounting are based on)."""
    B, H, W, C = x.shape
    _, _, Ho, Wo = conv_geometry(H, W, kh, kw, stride, padding)
    return B * Ho * Wo * kh * kw * C * x.dtype.itemsize


def _pick_implicit(implicit, x, kh, kw, stride, padding, bk=None):
    """Resolve the ``implicit=`` tri-state: None auto-selects by
    patch-tensor size — implicit when the patch is a real blow-up
    (kh*kw > 1) of at least ``_IMPLICIT_MIN_PATCH_BYTES``, AND the padded
    image block the kernel pins in VMEM stays under
    ``_IMPLICIT_MAX_IMAGE_BYTES``.  The BCS path additionally needs its
    packing block inside one tap (bk | Cin); an explicit
    ``implicit=True`` asserts that instead of silently falling back."""
    B, H, W, C = x.shape
    if implicit is None:
        if bk is not None and C % bk:
            return False
        ph, pw, _, _ = conv_geometry(H, W, kh, kw, stride, padding)
        image_bytes = ((H + ph[0] + ph[1]) * (W + pw[0] + pw[1]) * C
                       * x.dtype.itemsize)
        return (kh * kw > 1
                and image_bytes <= _IMPLICIT_MAX_IMAGE_BYTES
                and patch_bytes(x, kh, kw, stride, padding)
                >= _IMPLICIT_MIN_PATCH_BYTES)
    if implicit and bk is not None:
        assert C % bk == 0, (
            f"implicit conv needs bk={bk} | Cin={C} (K-blocks must not "
            f"straddle kernel taps)")
    return bool(implicit)


def sparse_conv2d(x, packed: PackedLayout, *, kh, kw, stride=1,
                  padding="SAME", bias=None, act="none", bm=128,
                  interpret=None, implicit=None):
    """x (B, H, W, Cin) * packed conv weight -> (B, Ho, Wo, Cout).

    ``packed`` is the PackedLayout of the im2col-lowered (Kh*Kw*Q, P) conv
    weight (``serve.compile.compile_model`` on a block-punched conv layer).
    The conv runs as ONE sparse GEMM: pruned kernel-position blocks are
    never read nor multiplied, and bias + activation fuse into the kernel
    epilogue.  ``implicit`` picks the x-operand strategy (None = auto by
    patch size, see ``_pick_implicit``): the materialized path extracts
    the full im2col patch tensor first; the implicit path
    (``bsr_conv2d_implicit``) gathers input rows inside the kernel and
    never allocates it — bit-identical outputs either way.  Depthwise
    convs are never packed (compile_model skips them with a logged
    reason), so this path only sees full convolutions."""
    B, H, W, C = x.shape
    assert packed.shape[0] == kh * kw * C, (
        f"layout K={packed.shape[0]} != kh*kw*Cin={kh * kw * C}")
    if packed.n_shards:
        # the implicit kernels are single-device (their epilogue gathers
        # per-launch); sharded conv layouts run the materialized GEMM,
        # whose bsr_matmul_packed dispatch handles the shard merge
        assert not implicit, "implicit conv does not support sharded layouts"
        implicit = False
    if _pick_implicit(implicit, x, kh, kw, stride, padding,
                      bk=packed.block[0]):
        return bsr_conv2d_implicit(x, packed, kh=kh, kw=kw, stride=stride,
                                   padding=padding, bias=bias, bm=bm,
                                   act=act, interpret=interpret)
    patches = im2col(x, kh, kw, stride, padding)
    _, Ho, Wo, K = patches.shape
    y = bsr_matmul_packed(patches.reshape(B * Ho * Wo, K), packed,
                          bias=bias, bm=bm, act=act, interpret=interpret)
    return y.reshape(B, Ho, Wo, y.shape[-1])


def sparse_conv2d_pattern(x, tap, *, kh, kw, stride=1, padding="SAME",
                          bias=None, act="none", bm=128, interpret=None,
                          implicit=None):
    """x (B, H, W, Cin) * tap-lowered conv weight -> (B, Ho, Wo, Cout).

    ``tap`` is the ``core.packed.TapLayout`` of a pattern/connectivity-
    pruned conv layer (``serve.compile.compile_model`` routes 4-D
    ``pattern``-scheme masks here).  Materialized mode: im2col + the
    Pallas tap-gather kernel — the patch matrix is first gathered down to
    ``tap.alive`` (rows pruned in EVERY filter are never materialized),
    then each filter group contracts only its own surviving taps (one
    launch per degree bin), bias + activation fused in the kernel step.
    Implicit mode (``implicit=True`` or auto by patch size): the
    tap-gather runs straight off the padded feature map
    (``tap_gather_conv_implicit``) — neither the patch tensor nor the
    alive band is ever allocated.  Bit-parity oracle: the masked dense
    ``lax.conv`` kept in ``models.convnet``."""
    B, H, W, C = x.shape
    assert tap.shape[0] == kh * kw * C, (
        f"layout K={tap.shape[0]} != kh*kw*Cin={kh * kw * C}")
    if tap.n_shards:
        # sharded tap layouts run materialized (see sparse_conv2d)
        assert not implicit, "implicit conv does not support sharded layouts"
        implicit = False
    if _pick_implicit(implicit, x, kh, kw, stride, padding):
        return tap_gather_conv_implicit(x, tap, kh=kh, kw=kw, stride=stride,
                                        padding=padding, bias=bias, bm=bm,
                                        act=act, interpret=interpret)
    patches = im2col(x, kh, kw, stride, padding)
    _, Ho, Wo, K = patches.shape
    band = patches.reshape(B * Ho * Wo, K)
    if tap.n_alive < K:
        # nonzero() is sorted, so a full-size alive index is exactly
        # arange(K): only gather when rows are actually dead everywhere
        band = jnp.take(band, tap.alive, axis=1)
    y = tap_gather_conv_packed(band, tap, bias=bias, bm=bm, act=act,
                               interpret=interpret)
    return y.reshape(B, Ho, Wo, y.shape[-1])


def sparse_expert_linear(x, packed: PackedLayout, bias=None, act="none",
                         bm=128, interpret=None):
    """Batched per-expert sparse GEMM: x (E, M, K) -> (E, M, N).

    ``packed`` carries a leading expert axis on every leaf (values
    (E, nb_b, L_b, bk, bn), perm (E, Nb), ...) — exactly what
    ``serve.compile._pack_stacked`` emits for MoE expert weights.  The
    packed kernel is ``jax.vmap``-ed over that axis, so all experts run as
    one batched launch per bin instead of E Python-level calls.

    Expert layouts are never column-sharded: under tensor parallelism the
    EXPERT axis is the shard axis (``distributed.sharding`` attaches the
    mesh "model" ``NamedSharding`` to the leading leaf dim for free), so
    a column-sharded expert layout here is a compile bug."""
    assert packed.n_shards == 0, (
        "MoE expert layouts shard along the expert axis, not block "
        "columns; serve.compile must exempt moe/ paths from CompileSpec.tp")

    def _fn(xe, le, be=None):
        return bsr_matmul_packed(xe, le, bias=be, bm=bm, act=act,
                                 interpret=interpret)

    if bias is not None:
        return jax.vmap(_fn)(x, packed, bias)
    return jax.vmap(lambda xe, le: _fn(xe, le))(x, packed)


def flops_saved(packed: PackedLayout) -> float:
    """Fraction of dense matmul FLOPs the kernel actually skips.

    The uniform CSC layout pads every block column of a bin to the bin's
    max degree, so the executed fraction is ``executed_blocks / (Kb*Nb)``
    — NOT the raw block density: imbalanced column degrees execute padding
    blocks.  Reordering/binning shrinks exactly this padding."""
    return packed.flops_saved


def padding_overhead(packed: PackedLayout) -> float:
    """Executed-block overhead of uniform padding vs ideal CSC."""
    return packed.padding_overhead
