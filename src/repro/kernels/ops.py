"""Jit'd dispatch layer over the sparse executor paths.

``sparse_linear`` picks the execution strategy the compiler framework
would emit for a pruned layer:
  density == 1        -> dense XLA matmul
  block-sparse (BCS)  -> Pallas bsr_matmul (skips pruned blocks)
  otherwise           -> masked-dense matmul (mask fused by XLA)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.kernels.bsr_matmul import bsr_matmul
from repro.kernels import ref


def pack(w, mask, block=(128, 128)):
    """Host-side packing of a pruned weight into the kernel layout."""
    b = BCS.from_dense(np.asarray(w), np.asarray(mask), block)
    values, k_idx, nnz = BCS.pad_to_uniform_csc(b)
    return {"values": values, "k_idx": k_idx, "nnz": nnz,
            "block": block, "shape": b.shape, "density": b.density}


def sparse_linear(x, packed=None, w=None, mask=None, bias=None, act="none",
                  bm=128, interpret=True):
    """x (..., K) -> (..., N) through whichever path applies."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    if packed is not None and M % min(bm, M) == 0:
        y = bsr_matmul(x2, packed["values"], packed["k_idx"], bias=bias,
                       bm=min(bm, M), act=act, interpret=interpret)
    else:
        y = ref.masked_matmul_ref(
            x2, w, mask if mask is not None else jnp.ones_like(w),
            bias=bias, act=act)
    return y.reshape(*lead, y.shape[-1])


def flops_saved(packed) -> float:
    """Fraction of dense matmul FLOPs skipped by the kernel."""
    return 1.0 - packed["density"]
