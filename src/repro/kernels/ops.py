"""Jit'd dispatch layer over the sparse executor paths.

``sparse_linear`` picks the execution strategy the compiler framework
would emit for a pruned layer:
  packed BCS layout    -> Pallas bsr_matmul (skips pruned blocks; ragged
                          M is zero-padded inside the kernel wrapper, so
                          the packed path never falls back to dense)
  dense weight (+mask) -> masked-dense matmul (mask fused by XLA)

``pack`` is the host-side codegen step: it converts a pruned weight into
the uniform CSC block layout the kernel consumes.  Results are memoized on
a content digest of (w, mask, block) so recompiles and repeated serve-path
setup never repack — packing cost is paid once per distinct weight."""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.kernels.bsr_matmul import bsr_matmul
from repro.kernels import ref

_PACK_CACHE: OrderedDict = OrderedDict()
_PACK_CACHE_MAX = 256
# byte bound (values + k_idx + nnz), evicted LRU: a count-only bound would
# happily pin GBs of packed multi-MB projections for the process lifetime
_PACK_CACHE_MAX_BYTES = 256 << 20


def _entry_bytes(out) -> int:
    return sum(int(np.prod(out[k].shape)) * out[k].dtype.itemsize
               for k in ("values", "k_idx", "nnz"))


def _digest(w: np.ndarray, mask: np.ndarray, block) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str((w.shape, str(w.dtype), block)).encode())
    h.update(np.ascontiguousarray(w).tobytes())
    h.update(np.ascontiguousarray(mask).tobytes())
    return h.hexdigest()


def pack(w, mask, block=(128, 128), use_cache=True):
    """Host-side packing of a pruned weight into the kernel layout.

    Returns {"values", "k_idx", "nnz", "block", "shape", "density"}.
    ``values``/``k_idx``/``nnz`` are device arrays; the rest is metadata.
    """
    w = np.asarray(w)
    mask = np.asarray(mask)
    key = _digest(w, mask, tuple(block)) if use_cache else None
    if key is not None and key in _PACK_CACHE:
        _PACK_CACHE.move_to_end(key)
        return dict(_PACK_CACHE[key])
    values, k_idx, nnz, density = BCS.pack_csc(w, mask, block)
    out = {"values": values, "k_idx": k_idx, "nnz": nnz,
           "block": tuple(block), "shape": tuple(w.shape),
           "density": density}
    if key is not None:
        _PACK_CACHE[key] = dict(out)
        total = sum(_entry_bytes(e) for e in _PACK_CACHE.values())
        while (len(_PACK_CACHE) > _PACK_CACHE_MAX
               or total > _PACK_CACHE_MAX_BYTES) and len(_PACK_CACHE) > 1:
            _, evicted = _PACK_CACHE.popitem(last=False)
            total -= _entry_bytes(evicted)
    return out


def clear_pack_cache():
    _PACK_CACHE.clear()


def sparse_linear(x, packed=None, w=None, mask=None, bias=None, act="none",
                  bm=128, interpret=None):
    """x (..., K) -> (..., N) through whichever path applies.

    With ``packed`` the Pallas BCS kernel always runs (ragged leading
    dims are flattened; ragged M is padded inside ``bsr_matmul``).
    ``interpret=None`` auto-detects the backend."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    if packed is not None:
        y = bsr_matmul(x2, packed["values"], packed["k_idx"], bias=bias,
                       bm=bm, act=act, interpret=interpret)
    else:
        y = ref.masked_matmul_ref(
            x2, w, mask if mask is not None else jnp.ones_like(w),
            bias=bias, act=act)
    return y.reshape(*lead, y.shape[-1])


def flops_saved(packed) -> float:
    """Fraction of dense matmul FLOPs the kernel actually skips.

    The uniform CSC layout pads every block column to the max column
    degree L, so the executed fraction is L·Nb / (Kb·Nb) = L/Kb — NOT the
    raw block density: imbalanced column degrees execute padding blocks.
    """
    Nb, L, bk, bn = packed["values"].shape
    Kb = packed["shape"][0] // packed["block"][0]
    return max(0.0, 1.0 - L / Kb)


def padding_overhead(packed) -> float:
    """Executed-block overhead of uniform padding vs ideal CSC: L·Nb/nnzb."""
    Nb, L, _, _ = packed["values"].shape
    nnzb = int(np.asarray(packed["nnz"]).sum())
    return (L * Nb) / max(nnzb, 1)
