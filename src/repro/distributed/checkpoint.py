"""Fault-tolerant checkpointing.

Design (DESIGN.md §5): step-stamped directories, per-host shard files,
manifest-last + atomic rename => a partially written checkpoint is never
picked up; restore scans for the newest COMPLETE step.  Restore re-shards
onto whatever mesh the restoring job has (elastic restarts: the array data
is mesh-agnostic; shardings are re-applied via device_put)."""
from __future__ import annotations

import json
import os
import pathlib

import numpy as np
import jax

from repro.models import module as M


def _to_numpy(v):
    a = np.asarray(v)
    if a.dtype.name == "bfloat16":      # numpy can't savez ml_dtypes
        a = a.astype(np.float32)        # lossless widening; restore recasts
    return a


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {M.path_str(p): _to_numpy(v) for p, v in flat}, treedef


def save(ckpt_dir, step: int, tree, host_id: int = 0, n_hosts: int = 1,
         meta: dict | None = None):
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)
    arrays, _ = _flatten(tree)
    np.savez(tmp / f"shard_{host_id}.npz", **arrays)
    # host 0 writes the manifest LAST; atomic rename publishes the step
    if host_id == 0:
        manifest = {"step": step, "n_hosts": n_hosts,
                    "keys": sorted(arrays.keys()), "meta": meta or {}}
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            return final
        os.replace(tmp, final)
        return final
    return tmp


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "MANIFEST.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir, tree_like, step: int | None = None,
            shardings=None, host_id: int = 0):
    """Restore into the structure of ``tree_like``; re-shard with
    ``shardings`` (same structure) when given — the elastic-restart path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / f"shard_{host_id}.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    if shardings is not None:
        flat_s = [s for _, s in
                  jax.tree_util.tree_flatten_with_path(shardings)[0]]
    else:
        flat_s = [None] * len(flat)
    for (p, like), sh in zip(flat, flat_s):
        arr = data[M.path_str(p)]
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), leaves), step
