"""Fault-tolerant checkpointing.

Design (DESIGN.md §5): step-stamped directories, per-host shard files,
manifest-last + atomic rename => a partially written checkpoint is never
picked up; restore scans for the newest COMPLETE step.  Restore re-shards
onto whatever mesh the restoring job has (elastic restarts: the array data
is mesh-agnostic; shardings are re-applied via device_put).

Integrity: the manifest carries a sha256 checksum + byte size per shard
file (``file_checksum`` is shared with the AOT artifact store in
``serve.artifacts``), and restore verifies them before deserializing —
bit rot or truncation raises a structured ``CheckpointError`` naming the
offending file instead of silently feeding garbage into ``np.load``.
Restoring into a tree whose structure, shapes, or dtype kinds differ from
what was saved also raises a ``CheckpointError`` naming the first
offending param path, instead of a raw ``KeyError`` (missing key) or a
shape mismatch deep inside ``tree_unflatten``.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib

import numpy as np
import jax

from repro.models import module as M


class CheckpointError(RuntimeError):
    """Structured restore failure: carries the checkpoint ``path`` and the
    failure-class ``code`` (``missing_key`` / ``unexpected_key`` /
    ``checksum`` / ``shape`` / ``dtype`` / ``missing_file``)."""

    def __init__(self, detail, *, code="invalid", path=None):
        self.code = code
        self.path = str(path) if path is not None else None
        where = f" [{self.path}]" if self.path else ""
        super().__init__(f"[{code}]{where} {detail}")


def file_checksum(path, algo: str = "sha256", chunk: int = 1 << 20) -> str:
    """Streaming content hash of one file — shared by the checkpoint
    manifest and the AOT artifact store (``serve.artifacts``)."""
    h = hashlib.new(algo)
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return h.hexdigest()
            h.update(block)


def _to_numpy(v):
    a = np.asarray(v)
    if a.dtype.name == "bfloat16":      # numpy can't savez ml_dtypes
        a = a.astype(np.float32)        # lossless widening; restore recasts
    return a


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {M.path_str(p): _to_numpy(v) for p, v in flat}, treedef


def save(ckpt_dir, step: int, tree, host_id: int = 0, n_hosts: int = 1,
         meta: dict | None = None):
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)
    arrays, _ = _flatten(tree)
    shard_name = f"shard_{host_id}.npz"
    np.savez(tmp / shard_name, **arrays)
    # host 0 writes the manifest LAST; atomic rename publishes the step
    if host_id == 0:
        shard_path = tmp / shard_name
        manifest = {"step": step, "n_hosts": n_hosts,
                    "keys": sorted(arrays.keys()), "meta": meta or {},
                    "checksums": {shard_name: {
                        "sha256": file_checksum(shard_path),
                        "bytes": shard_path.stat().st_size}}}
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            return final
        os.replace(tmp, final)
        return final
    return tmp


def available_steps(ckpt_dir) -> list:
    """All COMPLETE checkpoint steps (manifest published), newest first —
    the fallback order ``distributed.elastic.replica_restore`` walks when
    the newest step fails its integrity checks (a corrupt shard must cost
    a logged fallback to an older step, not a dead replica).  Torn steps
    (no manifest) are invisible here, exactly as for ``latest_step``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
             if d.name.startswith("step_")
             and (d / "MANIFEST.json").exists()]
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[0] if steps else None


def _verify_shard(d, shard_name):
    """Checksum + key-set verification against the step's manifest; every
    failure is a structured ``CheckpointError`` naming the file."""
    shard_path = d / shard_name
    if not shard_path.exists():
        raise CheckpointError(f"shard file {shard_name} is missing",
                              code="missing_file", path=d)
    manifest_path = d / "MANIFEST.json"
    if not manifest_path.exists():     # torn step: latest_step skips these
        raise CheckpointError("manifest is missing (torn checkpoint?)",
                              code="missing_file", path=d)
    manifest = json.loads(manifest_path.read_text())
    rec = manifest.get("checksums", {}).get(shard_name)
    if rec is not None:                # pre-checksum checkpoints: skip
        size = shard_path.stat().st_size
        if size != rec["bytes"]:
            raise CheckpointError(
                f"shard {shard_name} is {size} bytes, manifest says "
                f"{rec['bytes']} (truncated write?)", code="checksum",
                path=shard_path)
        digest = file_checksum(shard_path)
        if digest != rec["sha256"]:
            raise CheckpointError(
                f"shard {shard_name} sha256 {digest[:12]}... != manifest "
                f"{rec['sha256'][:12]}... (bit corruption?)",
                code="checksum", path=shard_path)
    return manifest


def _check_leaf(path, like, arr, d):
    """Shape/dtype-kind compatibility of one stored array against the
    restore target — a wrong-tree restore fails HERE with the param path,
    not as a shape error deep inside ``tree_unflatten``."""
    like_shape = getattr(like, "shape", None)
    if like_shape is not None and tuple(arr.shape) != tuple(like_shape):
        raise CheckpointError(
            f"param {path!r}: checkpoint shape {tuple(arr.shape)} != "
            f"restore target shape {tuple(like_shape)}", code="shape",
            path=d)
    like_dtype = getattr(like, "dtype", None)
    if like_dtype is not None:
        kind_of = (lambda dt: "f" if jax.numpy.issubdtype(dt,
                   jax.numpy.floating) else np.dtype(dt).kind)
        if kind_of(arr.dtype) != kind_of(like_dtype):
            raise CheckpointError(
                f"param {path!r}: checkpoint dtype {arr.dtype} is not "
                f"restorable into target dtype {like_dtype} (different "
                "dtype kind — wrong tree?)", code="dtype", path=d)


def restore(ckpt_dir, tree_like, step: int | None = None,
            shardings=None, host_id: int = 0):
    """Restore into the structure of ``tree_like``; re-shard with
    ``shardings`` (same structure) when given — the elastic-restart path.

    Raises ``CheckpointError`` (naming the offending path) when the shard
    fails its manifest checksum, when a param of ``tree_like`` is missing
    from the checkpoint, when the checkpoint carries params ``tree_like``
    does not expect, or when a param's shape/dtype kind is incompatible.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    d = ckpt_dir / f"step_{step:08d}"
    _verify_shard(d, f"shard_{host_id}.npz")
    data = np.load(d / f"shard_{host_id}.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    want = [M.path_str(p) for p, _ in flat]
    missing = sorted(set(want) - set(data.files))
    if missing:
        more = f" (+{len(missing) - 1} more)" if len(missing) > 1 else ""
        raise CheckpointError(
            f"param {missing[0]!r}{more} expected by the restore target "
            "is missing from the checkpoint — wrong tree?",
            code="missing_key", path=d)
    extra = sorted(set(data.files) - set(want))
    if extra:
        more = f" (+{len(extra) - 1} more)" if len(extra) > 1 else ""
        raise CheckpointError(
            f"checkpoint carries param {extra[0]!r}{more} the restore "
            "target does not expect — wrong tree?",
            code="unexpected_key", path=d)
    leaves = []
    if shardings is not None:
        flat_s = [s for _, s in
                  jax.tree_util.tree_flatten_with_path(shardings)[0]]
    else:
        flat_s = [None] * len(flat)
    for (p, like), sh in zip(flat, flat_s):
        arr = data[M.path_str(p)]
        _check_leaf(M.path_str(p), like, arr, d)
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), leaves), step
