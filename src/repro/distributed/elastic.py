"""Elastic scaling + straggler mitigation (DESIGN.md §5).

* ``choose_mesh_shape``: given the LIVE device count (after failures),
  pick the largest power-of-two (data, model) split that preserves the
  requested model-parallel degree — the framework restarts onto the
  shrunken mesh and `checkpoint.restore(..., shardings=new)` re-shards.
* ``replica_restore``: the replica cold-start path — newest complete
  checkpoint + the AOT artifact store (``serve.artifacts``), so a replica
  spun up under load serves already-packed layouts in milliseconds
  instead of repaying the §4.3 compile; any stale/corrupt artifact is
  detected (digest, checksums, layout validation) and degrades to a
  fresh pack through the same ``compile_model`` front door.
* Straggler mitigation is structural: the data pipeline is a pure function
  of (seed, step, shard) (repro.data.pipeline), so a backup host can
  recompute any shard with zero coordination; `backup_step_threshold`
  implements the classic 'launch backup after p99' policy hook.
"""
from __future__ import annotations

import jax

from repro.launch.mesh import make_mesh


def choose_mesh_shape(n_devices: int, model_parallel: int = 16,
                      want_pods: int = 1):
    """Largest power-of-2 mesh <= n_devices keeping `model_parallel`."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    rest = n_devices // mp
    # peel a pod axis if asked and divisible
    if want_pods > 1 and rest % want_pods == 0:
        return (want_pods, rest // want_pods, mp), ("pod", "data", "model")
    dp = 1
    while dp * 2 <= rest:
        dp *= 2
    return (dp, mp), ("data", "model")


def replica_restore(ckpt_dir, tree_like, *, mapping=(), masks=None,
                    artifact_dir=None, step=None, shardings=None,
                    spec=None, **compile_kw):
    """Elastic replica start: restore the newest complete checkpoint, then
    load-or-compile the packed execution params through the SAME artifact
    front door as ``launch.serve --artifacts``.

    ``masks=None`` derives masks from the zeros already baked into the
    restored weights (checkpoints hold post-``apply_masks`` params), so a
    replica needs nothing beyond the checkpoint + the artifact store.
    ``spec`` (a ``serve.compile.CompileSpec``) carries the compile
    options; extra ``compile_kw`` still forwards the legacy per-option
    kwargs through ``compile_model``'s deprecation shim.
    Returns ``(exec_params, report, step)`` — ``(None, None, None)`` when
    no checkpoint exists yet.  A missing/stale/corrupt artifact costs a
    repack (logged, structured reason); it can never mis-execute.

    Double-fault tolerance: with ``step=None`` a checkpoint step that
    fails its integrity checks (``CheckpointError``: bad checksum,
    truncated shard, missing file) logs the structured reason and falls
    back to the NEXT older complete step — combined with the artifact
    fallback above, a replica survives a corrupt newest checkpoint AND a
    corrupt artifact in the same start (locked by a double-fault test).
    An explicitly pinned ``step`` never substitutes: its failure raises.
    The grafted/compiled tree additionally passes through
    ``serve.compile.degrade_invalid_layers`` so a layout corrupted after
    the store's own checks serves masked-dense instead of wrong.
    """
    import logging

    from repro.distributed import checkpoint as CKPT
    from repro.serve.compile import compile_model, degrade_invalid_layers

    log = logging.getLogger("repro.distributed.elastic")
    steps = [step] if step is not None else CKPT.available_steps(ckpt_dir)
    params = restored = None
    for s in steps:
        try:
            params, restored = CKPT.restore(ckpt_dir, tree_like, step=s,
                                            shardings=shardings)
            break
        except CKPT.CheckpointError as e:
            if step is not None:
                raise   # caller pinned this step: no silent substitution
            log.warning("checkpoint step %d failed integrity [%s] — "
                        "falling back to the next older step: %s",
                        s, e.code, e)
    if params is None:
        return None, None, None
    exec_params, report = compile_model(params, masks, mapping, spec=spec,
                                        artifact_dir=artifact_dir,
                                        **compile_kw)
    exec_params, report, _ = degrade_invalid_layers(exec_params, report)
    return exec_params, report, restored


def rebuild_mesh(model_parallel=16, want_pods=1):
    n = len(jax.devices())
    shape, axes = choose_mesh_shape(n, model_parallel, want_pods)
    return make_mesh(shape, axes)


class StragglerMonitor:
    """Track per-step durations; signal when a step exceeds k x median —
    the driver then re-issues the step's shards to backup hosts (the data
    pipeline determinism makes the recompute exact)."""

    def __init__(self, k: float = 3.0, window: int = 50):
        self.k = k
        self.window = window
        self.durations = []

    def observe(self, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = self.durations[-self.window:]
        if len(hist) < 5:
            return False
        med = sorted(hist)[len(hist) // 2]
        return seconds > self.k * med
