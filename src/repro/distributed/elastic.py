"""Elastic scaling + straggler mitigation (DESIGN.md §5).

* ``choose_mesh_shape``: given the LIVE device count (after failures),
  pick the largest power-of-two (data, model) split that preserves the
  requested model-parallel degree — the framework restarts onto the
  shrunken mesh and `checkpoint.restore(..., shardings=new)` re-shards.
* Straggler mitigation is structural: the data pipeline is a pure function
  of (seed, step, shard) (repro.data.pipeline), so a backup host can
  recompute any shard with zero coordination; `backup_step_threshold`
  implements the classic 'launch backup after p99' policy hook.
"""
from __future__ import annotations

import jax

from repro.launch.mesh import make_mesh


def choose_mesh_shape(n_devices: int, model_parallel: int = 16,
                      want_pods: int = 1):
    """Largest power-of-2 mesh <= n_devices keeping `model_parallel`."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    rest = n_devices // mp
    # peel a pod axis if asked and divisible
    if want_pods > 1 and rest % want_pods == 0:
        return (want_pods, rest // want_pods, mp), ("pod", "data", "model")
    dp = 1
    while dp * 2 <= rest:
        dp *= 2
    return (dp, mp), ("data", "model")


def rebuild_mesh(model_parallel=16, want_pods=1):
    n = len(jax.devices())
    shape, axes = choose_mesh_shape(n, model_parallel, want_pods)
    return make_mesh(shape, axes)


class StragglerMonitor:
    """Track per-step durations; signal when a step exceeds k x median —
    the driver then re-issues the step's shards to backup hosts (the data
    pipeline determinism makes the recompute exact)."""

    def __init__(self, k: float = 3.0, window: int = 50):
        self.k = k
        self.window = window
        self.durations = []

    def observe(self, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = self.durations[-self.window:]
        if len(hist) < 5:
            return False
        med = sorted(hist)[len(hist) // 2]
        return seconds > self.k * med
