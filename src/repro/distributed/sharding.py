"""Sharding policy: param partition rules per architecture + activation
constraints (the ``Dist`` helper threaded through model code).

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  Batch/DP shards over (pod, data); TP/EP/SP over model; FSDP
(weight + optimizer-state sharding over the data axes) switches on for the
>=70B archs (llama-3.2-90b, kimi-k2-1t) so Adam/Adafactor state fits HBM.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import module as M


@dataclass
class Dist:
    mesh: Mesh
    batch_axes: tuple = ("data",)   # () when global batch < dp degree
    model_axis: str = "model"
    kv_shardable: bool = True       # n_kv_heads % tp == 0
    expert_sharded: bool = False    # n_experts % tp == 0
    vocab_shardable: bool = True    # vocab % tp == 0
    mode: str = "tp"                # "tp" | "fsdp" (see ArchConfig)

    @property
    def tp(self):
        return self.mesh.shape[self.model_axis]

    @property
    def dp(self):
        d = 1
        for a in self.batch_axes:
            d *= self.mesh.shape[a]
        return d

    def _c(self, x, *spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def _b(self):
        return self.batch_axes if self.batch_axes else None

    # -- activation constraints used inside models --------------------------
    def shard_activations(self, x):            # (B, S, D)
        return self.shard_residual(x)

    def shard_residual(self, x):               # (B, S, D)
        """Megatron-style sequence-parallel residual stream: the seq dim is
        sharded over the model axis BETWEEN blocks, so the per-layer scan
        carry is 1/tp the size (and wo/down all-reduces lower to
        reduce-scatter + all-gather).  Falls back to replicated when seq
        isn't divisible (decode: seq == 1)."""
        if x.shape[1] % self.tp == 0:
            return self._c(x, self._b(), self.model_axis, None)
        return self._c(x, self._b(), None, None)

    def shard_logits(self, x):                 # (B, S, V)
        if self.mode == "fsdp" or not self.vocab_shardable:
            # seq stays model-sharded through the head matmul
            if x.shape[1] % self.tp == 0:
                return self._c(x, self._b(), self.model_axis, None)
            return self._c(x, self._b(), None, None)
        return self._c(x, self._b(), None, self.model_axis)

    def shard_attn_q(self, q, mode):           # (B, S, H, hd)
        if self.mode == "fsdp" or mode == "seq":
            # context-parallel: q seq-sharded, full heads per device
            if q.shape[1] % self.tp == 0:
                return self._c(q, self._b(), self.model_axis, None, None)
            return q
        return self._c(q, self._b(), None, self.model_axis, None)

    def shard_attn_kv(self, k, mode, n_kv):    # (B, S, KV, hd)
        if self.mode == "fsdp" or mode == "seq":
            # force the model-axis all-gather HERE: compact KV-form, bf16 —
            # 2(dtype) x G(heads) cheaper than letting GSPMD gather the
            # f32 expanded form inside the flash scan (§Perf iter 4)
            return self._c(k, self._b(), None, None, None)
        if mode == "heads" and self.kv_shardable:
            return self._c(k, self._b(), None, self.model_axis, None)
        return self._c(k, self._b(), None, None, None)

    def shard_cache(self, c):                  # (B, S, KV, hd): S-sharded
        return self._c(c, self._b(), self.model_axis, None, None)

    def shard_heads(self, x):                  # ssm (B, S, H, P)
        return self._c(x, self._b(), None, self.model_axis, None)

    def shard_experts(self, x):                # moe (G, E, C, D)
        if self.expert_sharded:
            # G (token groups) stays batch-sharded; E expert-parallel.
            # Leaving G unsharded makes every device materialize ALL
            # global tokens' dispatch — an 18 GB/layer all-gather on
            # kimi-1T (§Perf kimi iter 1).
            g = self._b() if x.shape[0] % max(self.dp, 1) == 0 and \
                self.batch_axes else None
            return self._c(x, g, self.model_axis, None, None)
        return x


def make_dist(mesh: Mesh, cfg: ArchConfig, global_batch: int,
              mode: str = "tp") -> Dist:
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    batch_axes = tuple(axes) if global_batch % dp == 0 else ()
    tp = mesh.shape["model"]
    return Dist(mesh=mesh, batch_axes=batch_axes,
                kv_shardable=(cfg.n_kv_heads % tp == 0) if cfg.n_kv_heads else False,
                expert_sharded=(cfg.n_experts % tp == 0) if cfg.n_experts else False,
                vocab_shardable=cfg.vocab % tp == 0,
                mode=mode)


# ---------------------------------------------------------------------------
# Packed-layout partition specs (tensor-parallel sharded layouts)
# ---------------------------------------------------------------------------

def _axis_at(leaf, pos, axis):
    """P with ``axis`` at dim ``pos`` of ``leaf``, None-safe replicate."""
    if leaf is None:
        return None
    nd = np.ndim(leaf)
    spec = [None] * nd
    spec[pos] = axis
    return P(*spec)


def _replicated(leaf):
    """Fully-replicated spec, None passed through (absent leaf)."""
    return None if leaf is None else P()


def layout_partition_specs(layout, model_axis: str = "model"):
    """Per-leaf ``PartitionSpec`` tree for a ``PackedLayout``/``TapLayout``.

    Column-sharded layouts (``n_shards`` > 0, ``core.bcs.shard_columns``)
    map the shard stack dim — the LAST stack dim, sitting immediately
    before each leaf's per-bin dims — onto the mesh model axis: values at
    ndim-5 (tap: ndim-4), index leaves at ndim-3, nnz/perm at ndim-2.
    ``inv_perm`` (flat, global) and ``alive`` stay replicated: the
    ``merge_shards`` epilogue gathers through them after the all-gather.
    Scale leaves share the values' leading dims, so their shard dim sits
    at the same rank-relative position.  Unsharded layouts replicate
    every leaf.  Returns the same layout class with each array leaf
    replaced by its spec — pytree-compatible with the layout itself, so
    it feeds ``jax.device_put`` / ``NamedSharding`` construction directly.
    """
    import dataclasses as _dc
    from repro.core.packed import PackedLayout, TapLayout

    def tmap(fn, leaf):
        if leaf is None:
            return None
        if isinstance(leaf, tuple):
            return tuple(fn(x) for x in leaf)
        return fn(leaf)

    if not layout.n_shards:
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(layout),
            [P() for _ in jax.tree_util.tree_leaves(layout)])
    if isinstance(layout, PackedLayout):
        lead = np.ndim(layout.values[0]) - 5
    else:
        assert isinstance(layout, TapLayout)
        lead = np.ndim(layout.values[0]) - 4
    shard = lambda x: _axis_at(x, lead, model_axis)  # noqa: E731
    out = _dc.replace(
        layout,
        values=tmap(shard, layout.values),
        nnz=shard(layout.nnz),
        perm=shard(layout.perm),
        inv_perm=_replicated(layout.inv_perm),
        scales=tmap(shard, layout.scales))
    if isinstance(layout, PackedLayout):
        return _dc.replace(out, k_idx=tmap(shard, layout.k_idx))
    return _dc.replace(out, t_idx=tmap(shard, layout.t_idx),
                       k_full=tmap(shard, layout.k_full),
                       alive=_replicated(layout.alive))


def expert_layout_specs(layout, model_axis: str = "model"):
    """Specs for an expert-parallel MoE layout stack: every array leaf
    (values, k_idx, nnz, perm, inv_perm, scales) carries the expert axis
    in front, so each shards at dim 0 over the model axis — the free
    sharding ``sparse_expert_linear`` exploits; column sharding
    (``n_shards``) must never reach these layouts."""
    assert layout.n_shards == 0, \
        "expert layouts shard along experts, not block columns"
    leaves, treedef = jax.tree_util.tree_flatten(layout)
    return jax.tree_util.tree_unflatten(
        treedef, [_axis_at(x, 0, model_axis) for x in leaves])


def layout_shardings(layout, mesh: Mesh, model_axis: str = "model"):
    """``NamedSharding`` tree for a layout on ``mesh`` (see
    ``layout_partition_specs``)."""
    specs = layout_partition_specs(layout, model_axis)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def shard_packed_tree(params, mesh: Mesh, model_axis: str = "model"):
    """Device-put every ``"packed"`` layout in a compiled param tree with
    its shard-axis ``NamedSharding`` (column-sharded leaves split over the
    model axis, everything else replicated) — the placement step between
    ``serve.compile.compile_model(spec=CompileSpec(tp=...))`` and serving
    on a real multi-device mesh.  Non-layout leaves are left alone."""
    from repro.core.packed import PackedLayout, TapLayout

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {k: walk(v) for k, v in node.items()}
        pk = out.get("packed")
        if isinstance(pk, (PackedLayout, TapLayout)):
            out["packed"] = jax.device_put(
                pk, layout_shardings(pk, mesh, model_axis))
        return out

    return walk(params)


# ---------------------------------------------------------------------------
# Param partition rules (path-regex -> right-aligned PartitionSpec)
# ---------------------------------------------------------------------------

def _fsdp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def needs_fsdp(cfg: ArchConfig) -> bool:
    # rough dense-equivalent param count; FSDP when a model-only shard of
    # Adam state would blow 16 GB HBM (>= ~30B params)
    return cfg.name in ("kimi-k2-1t-a32b", "llama-3.2-vision-90b")


def param_rules(cfg: ArchConfig, mesh: Mesh):
    f = _fsdp_axes(mesh) if needs_fsdp(cfg) else None
    mdl = "model"
    rules = [
        # MoE experts: (E, D, F) / (E, F, D) — EP on experts when divisible,
        # otherwise TP on the hidden dim; FSDP on D for the 1T arch.
        (r"moe/(gate|up)/w", P(mdl, f, None) if cfg.n_experts % mesh.shape[mdl] == 0
         else P(None, f, mdl)),
        (r"moe/down/w", P(mdl, None, f) if cfg.n_experts % mesh.shape[mdl] == 0
         else P(None, mdl, f)),
        (r"moe/router", P()),
        # attention projections
        (r"attn/wq/w|xattn/wq/w", P(f, mdl)),
        (r"attn/w[kv]/w|xattn/w[kv]/w",
         P(f, mdl) if cfg.n_kv_heads % mesh.shape[mdl] == 0 else P(f, None)),
        (r"attn/wo/w|xattn/wo/w", P(mdl, f)),
        # dense FFN
        (r"ffn/(gate|up)/w", P(f, mdl)),
        (r"ffn/down/w", P(mdl, f)),
        # SSM
        (r"ssm/in_proj/w", P(f, mdl)),
        (r"ssm/out_proj/w", P(mdl, f)),
        (r"ssm/(conv|A_log|D|dt_bias|norm)", P()),
        # embeddings: vocab-sharded over model (loss is vocab-parallel);
        # odd vocabs fall back to d_model-sharded tables
        (r"embed/table|head/table",
         P(mdl, f) if cfg.vocab % mesh.shape[mdl] == 0 else P(None, mdl)),
        # norms / scalars
        (r"ln|norm|gate$|scale|b$", P()),
    ]
    return rules


def _fsdp_axis_options(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    opts = [axes + ("model",), ("model",)]
    if axes:
        opts.append(axes)
    return opts


def fsdp_leaf_spec(shape, mesh: Mesh) -> P:
    """ZeRO-3 spec: shard one dim over as many mesh axes as divide it
    (prefer the output dim, then the input dim, then replicate)."""
    for dim in (len(shape) - 1, max(len(shape) - 2, 0)):
        for combo in _fsdp_axis_options(mesh):
            size = 1
            for a in combo:
                size *= mesh.shape[a]
            if shape[dim] % size == 0 and shape[dim] >= size:
                spec = [None] * len(shape)
                spec[dim] = combo if len(combo) > 1 else combo[0]
                return P(*spec)
    return P()


def param_specs(params, cfg: ArchConfig, mesh: Mesh, mode: str = "tp"):
    if mode == "tp":
        return M.spec_from_rules(params, param_rules(cfg, mesh))
    # fsdp (training): dense weights ZeRO-3 sharded; MoE experts keep the
    # EP rules (expert dim over model + fsdp axes); scalars replicated.
    import re
    moe_rules = [(pat, s) for pat, s in param_rules(cfg, mesh)
                 if pat.startswith("moe")]

    def assign(path, leaf):
        s = M.path_str(path)
        for pat, spec in moe_rules:
            if re.search(pat, s):
                pad = leaf.ndim - len(spec)
                return P(*([None] * max(pad, 0) + list(spec))) if pad >= 0 \
                    else P(*spec[-leaf.ndim:])
        if leaf.ndim < 2 or re.search(r"ln|norm|gate$|scale|A_log|dt_bias|D$",
                                      s):
            return P()
        if "head/table" in s:
            # output head wants VOCAB-sharded (vocab-parallel loss);
            # D-sharding would all-reduce (B,S,V) logits or all-gather the
            # f32-converted table (§Perf kimi iter 2)
            for combo in _fsdp_axis_options(mesh):
                size = 1
                for a in combo:
                    size *= mesh.shape[a]
                if leaf.shape[0] % size == 0:
                    return P(combo if len(combo) > 1 else combo[0], None)
            return fsdp_leaf_spec(leaf.shape, mesh)
        if "embed/table" in s:
            # input embedding wants D-sharded (lookup gathers stay local)
            for combo in _fsdp_axis_options(mesh):
                size = 1
                for a in combo:
                    size *= mesh.shape[a]
                if leaf.shape[1] % size == 0:
                    return P(None, combo if len(combo) > 1 else combo[0])
            return P()
        # strip the scanned-layer leading dim from the sharding decision
        stacked = leaf.ndim >= 3 and any(t in s for t in
                                         ("layers", "enc/", "dec/", "groups"))
        core = leaf.shape[1:] if stacked else leaf.shape
        spec = fsdp_leaf_spec(core, mesh)
        pad = leaf.ndim - len(spec)
        return P(*([None] * max(pad, 0) + list(spec)))

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params, cfg: ArchConfig, mesh: Mesh):
    specs = param_specs(params, cfg, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def opt_state_specs(opt_abs, p_specs, kind: str):
    """Optimizer-state PartitionSpecs mirroring the param specs.

    AdamW m/v share the param spec.  Adafactor's factored vr/vc drop the
    last / second-to-last dim of the param spec respectively."""
    if kind == "adamw":
        return {"m": p_specs, "v": p_specs, "step": P()}
    assert kind == "adafactor"

    def fspec(pspec, fdict):
        if "vr" in fdict:
            s = list(pspec)
            return {"vr": P(*s[:-1]), "vc": P(*(s[:-2] + s[-1:]))}
        return {"v": pspec}

    flat_s, treedef = jax.tree_util.tree_flatten(
        p_specs, is_leaf=lambda x: isinstance(x, P))
    flat_f = treedef.flatten_up_to(opt_abs["f"])
    f_specs = treedef.unflatten(
        [fspec(s, f) for s, f in zip(flat_s, flat_f)])
    return {"f": f_specs, "step": P()}


# ---------------------------------------------------------------------------
# Gradient compression (int8 stochastic rounding) for the cross-pod
# all-reduce — demonstrates the distributed-optimization hook; applied via
# shard_map over the pod axis in the train driver when enabled.
# ---------------------------------------------------------------------------

def quantize_int8(x, key):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    scaled = x / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_allreduce(x, key, axis_name: str):
    """int8-quantized psum along ``axis_name`` (use inside shard_map).  The
    wire payload is 4x smaller; scales are reduced in fp32."""
    q, scale = quantize_int8(x, key)
    # dequantize-then-reduce keeps the math simple while modelling the
    # 4x payload; a production impl reduces int8 payloads ring-wise.
    xsum = jax.lax.psum(dequantize_int8(q, scale), axis_name)
    return xsum
