"""Crash-safe AOT store for compiled (packed) model artifacts.

``BENCH_e2e_sparse`` puts cold packing at ~0.4-1.5 s per model vs ~6-9 ms
content-cached — fatal for elastic serving where replicas spin up under
load.  This module persists the §4.3 compile result (every
``core.packed.PackedLayout``/``TapLayout`` plus the compile report) so a
replica loads weights *already packed*:

    serve.compile.compile_model(..., artifact_dir=...)   # the front door
    launch.serve --artifacts DIR                         # CLI
    distributed.elastic.replica_restore(...)             # replica restart

On-disk format — content-addressed, one directory per model digest::

    <artifact_dir>/<digest>/arrays.npz      every layout leaf, path-keyed
    <artifact_dir>/<digest>/MANIFEST.json   format version, pack key,
                                            per-file sha256 + byte sizes,
                                            per-layer layout specs, the
                                            compile report

The digest (``model_digest``) extends the ``kernels.ops.pack`` content-
digest contract to the whole model: weights, masks, mapping, and every
compile knob that changes the produced layouts.  Writers stage into a
``.tmp_*`` sibling and publish with one atomic ``os.replace`` AFTER the
manifest (checksums included) hits disk — the same manifest-last
discipline as ``distributed.checkpoint``, whose ``file_checksum`` this
module shares — so a crashed writer leaves an ignored husk, never a
half-written artifact.

Load is paranoid by construction: digest match -> per-file checksum ->
spec/shape check -> full ``core.validate`` layout validation.  EVERY
failure (missing artifact, stale digest, version skew, checksum mismatch,
truncation, corrupt payload, layout-invariant violation) raises a
structured ``ArtifactError``/``LayoutError``; ``load_grafted`` logs the
reason and returns None so the caller falls back to a fresh pack — a bad
artifact can cost a repack, never a wrong output.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import pathlib
import shutil

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.packed import PackedLayout, TapLayout
from repro.core.validate import LayoutError, validate_layout
from repro.distributed.checkpoint import file_checksum
from repro.models.module import path_str

log = logging.getLogger("repro.serve.artifacts")

# v2: quantized value leaves (int8 values + fp32 ``scales``, see
# ``core.quant``) join the layout serialization, the manifest stores the
# typed CompileReport + CompileSpec, and the digest hashes the spec's
# digest fields — older fp-only artifacts fail the version check and
# repack instead of misloading
FORMAT_VERSION = 2
MANIFEST_FILE = "MANIFEST.json"
ARRAYS_FILE = "arrays.npz"


class ArtifactError(RuntimeError):
    """Base of the artifact-failure taxonomy; ``code`` is the stable tag
    the fallback log carries."""

    code = "artifact"

    def __init__(self, detail, *, path=None):
        self.detail = detail
        self.path = str(path) if path is not None else None
        where = f" [{self.path}]" if self.path else ""
        super().__init__(f"[{self.code}]{where} {detail}")


class ArtifactMissing(ArtifactError):
    """No artifact published for this digest (cold start, or every
    existing artifact is stale)."""

    code = "missing"


class ArtifactDigestMismatch(ArtifactError):
    """Manifest pack key disagrees with the requested digest — a stale or
    relocated artifact."""

    code = "digest_mismatch"


class ArtifactVersionSkew(ArtifactError):
    """Artifact written under a different format version."""

    code = "version_skew"


class ArtifactChecksumError(ArtifactError):
    """A payload file fails its manifest checksum or byte size (bit
    corruption / truncation)."""

    code = "checksum"


class ArtifactCorrupt(ArtifactError):
    """The artifact is structurally unreadable: manifest/leaves missing,
    bad JSON, or leaf shapes disagreeing with the manifest spec."""

    code = "corrupt"


# ---------------------------------------------------------------------------
# Model digest — the cache key an artifact is addressed by
# ---------------------------------------------------------------------------

def _hash_tree(h, tree, tag):
    h.update(f"<{tag}>".encode())
    if tree is None:
        h.update(b"none")
        return
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        a = np.asarray(leaf)
        h.update(path_str(p).encode())
        h.update(str((a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())


def model_digest(params, masks, mapping, *, spec=None, **legacy) -> str:
    """Content digest of everything that determines the compile result:
    the weights, the masks, the scheme mapping, and the ``CompileSpec``
    digest fields — exactly the knobs that change the produced layouts
    (``keep_dense``/``implicit`` are serving-time, so they stay out of the
    key).  Pass ``spec=CompileSpec(...)``; the historical keyword pile
    still resolves through the same shim as ``compile_model``, and both
    spellings of an equivalent compile digest identically.  Extends the
    per-layer ``kernels.ops.pack`` cache-key contract to the whole model —
    two compiles share an artifact iff they would produce identical
    layouts."""
    from repro.serve.compile import resolve_spec
    import warnings
    with warnings.catch_warnings():
        # the shim's DeprecationWarning belongs to compile_model's surface;
        # digests are computed internally on every artifact lookup
        warnings.simplefilter("ignore", DeprecationWarning)
        spec = resolve_spec(spec, **legacy)
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(("repro-artifact", FORMAT_VERSION,
                   [(pat, repr(choice)) for pat, choice in mapping],
                   spec.digest_fields())).encode())
    _hash_tree(h, params, "params")
    _hash_tree(h, masks, "masks")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Layout (de)serialization
# ---------------------------------------------------------------------------

def _to_numpy(v):
    a = np.asarray(v)
    if a.dtype.name == "bfloat16":      # numpy can't savez ml_dtypes
        a = a.astype(np.float32)        # lossless widening; load recasts
    return a


def _layout_leaves(layout):
    """(name, leaf-or-None) pairs in a fixed, reconstructible order."""
    if isinstance(layout, PackedLayout):
        for b in range(layout.n_bins):
            yield f"values.{b}", layout.values[b]
            yield f"k_idx.{b}", layout.k_idx[b]
            if layout.scales is not None:
                yield f"scales.{b}", layout.scales[b]
        yield "nnz", layout.nnz
        yield "perm", layout.perm
        yield "inv_perm", layout.inv_perm
    else:
        for b in range(layout.n_bins):
            yield f"values.{b}", layout.values[b]
            yield f"t_idx.{b}", layout.t_idx[b]
            if layout.k_full is not None:
                yield f"k_full.{b}", layout.k_full[b]
            if layout.scales is not None:
                yield f"scales.{b}", layout.scales[b]
        yield "nnz", layout.nnz
        yield "alive", layout.alive
        yield "perm", layout.perm
        yield "inv_perm", layout.inv_perm


def _layout_spec(layout):
    """JSON-serializable static description: aux data + per-leaf
    dtype/shape (the true dtype, so bf16 survives the f32 widening)."""
    leaves = {name: {"dtype": jnp.asarray(leaf).dtype.name,
                     "shape": list(np.shape(leaf))}
              for name, leaf in _layout_leaves(layout) if leaf is not None}
    if isinstance(layout, PackedLayout):
        return {"layout": "packed", "n_bins": layout.n_bins,
                "block": list(layout.block), "shape": list(layout.shape),
                "conv_taps": ([list(t) for t in layout.conv_taps]
                              if layout.conv_taps is not None else None),
                "n_shards": layout.n_shards,
                "leaves": leaves}
    return {"layout": "tap", "n_bins": layout.n_bins,
            "group": layout.group, "shape": list(layout.shape),
            "n_shards": layout.n_shards,
            "leaves": leaves}


def _layout_from_spec(lpath, spec, data):
    """Rebuild one layout from its manifest spec + the arrays bundle;
    raises ``ArtifactCorrupt`` on any missing or spec-divergent leaf."""
    leaves = spec["leaves"]

    def _get(name, required=True):
        rec = leaves.get(name)
        if rec is None:
            if required:
                raise ArtifactCorrupt(
                    f"layer {lpath!r}: required leaf {name!r} absent from "
                    "the manifest spec")
            return None
        key = f"{lpath}::{name}"
        if key not in data:
            raise ArtifactCorrupt(
                f"layer {lpath!r}: leaf {name!r} missing from "
                f"{ARRAYS_FILE}")
        a = data[key]
        if list(a.shape) != list(rec["shape"]):
            raise ArtifactCorrupt(
                f"layer {lpath!r}: leaf {name!r} shape {tuple(a.shape)} "
                f"!= manifest {tuple(rec['shape'])}")
        out = jnp.asarray(a)
        if out.dtype.name != rec["dtype"]:
            out = out.astype(rec["dtype"])
        return out

    n_bins = int(spec["n_bins"])
    has_scales = "scales.0" in leaves
    scales = (tuple(_get(f"scales.{b}") for b in range(n_bins))
              if has_scales else None)
    if spec["layout"] == "packed":
        return PackedLayout(
            values=tuple(_get(f"values.{b}") for b in range(n_bins)),
            k_idx=tuple(_get(f"k_idx.{b}") for b in range(n_bins)),
            nnz=_get("nnz"),
            perm=_get("perm", required=False),
            inv_perm=_get("inv_perm", required=False),
            block=tuple(spec["block"]), shape=tuple(spec["shape"]),
            conv_taps=(tuple(tuple(t) for t in spec["conv_taps"])
                       if spec.get("conv_taps") is not None else None),
            scales=scales,
            n_shards=int(spec.get("n_shards", 0)))
    if spec["layout"] == "tap":
        has_kfull = "k_full.0" in leaves
        return TapLayout(
            values=tuple(_get(f"values.{b}") for b in range(n_bins)),
            t_idx=tuple(_get(f"t_idx.{b}") for b in range(n_bins)),
            k_full=(tuple(_get(f"k_full.{b}") for b in range(n_bins))
                    if has_kfull else None),
            nnz=_get("nnz"), alive=_get("alive"),
            perm=_get("perm", required=False),
            inv_perm=_get("inv_perm", required=False),
            group=int(spec["group"]), shape=tuple(spec["shape"]),
            scales=scales,
            n_shards=int(spec.get("n_shards", 0)))
    raise ArtifactCorrupt(
        f"layer {lpath!r}: unknown layout kind {spec['layout']!r}")


# ---------------------------------------------------------------------------
# Save / load
# ---------------------------------------------------------------------------

def _resolve(tree, lpath):
    node = tree
    for part in lpath.split("/") if lpath else ():
        node = node[part]
    return node


def _packed_layers(exec_params, report):
    """{layer node path: layout} for every packed row of the report."""
    out = {}
    for row in report:
        if not row.get("packed"):
            continue
        wpath = row["path"]
        lpath = wpath[:-2] if wpath.endswith("/w") else ""
        out[lpath] = _resolve(exec_params, lpath)["packed"]
    return out


def save_artifact(artifact_dir, key, exec_params, report, *,
                  meta=None, validate=True):
    """Publish the compile result under ``<artifact_dir>/<key>``.

    Stages into a ``.tmp_*`` sibling, writes the arrays bundle, then the
    manifest (format version, pack key, per-file sha256 + sizes, layer
    specs, report), then publishes with one atomic ``os.replace`` — a
    crash at any point leaves either the previous state or a ``.tmp_*``
    husk loaders never read.  Content-addressed: if this digest is
    already published at the CURRENT format version (or a concurrent
    writer wins the rename race) the existing artifact is kept; an
    artifact left at this key by an older format version is replaced, so
    a version bump costs exactly one repack per key, not one per start.
    Returns the final path.
    """
    artifact_dir = pathlib.Path(artifact_dir)
    final = artifact_dir / key
    if final.exists():
        try:
            man = json.loads((final / MANIFEST_FILE).read_text())
            if man.get("format_version") == FORMAT_VERSION:
                return final
        except (OSError, ValueError):
            pass                       # unreadable manifest: replace it
        shutil.rmtree(final, ignore_errors=True)
    layers = _packed_layers(exec_params, report)
    if validate:
        for lpath, layout in layers.items():
            validate_layout(layout, path=lpath)
    arrays, specs = {}, {}
    for lpath, layout in layers.items():
        specs[lpath] = _layout_spec(layout)
        for name, leaf in _layout_leaves(layout):
            if leaf is not None:
                arrays[f"{lpath}::{name}"] = _to_numpy(leaf)
    tmp = artifact_dir / f".tmp_{key}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    np.savez(tmp / ARRAYS_FILE, **arrays)
    arrays_path = tmp / ARRAYS_FILE
    manifest = {
        "format_version": FORMAT_VERSION,
        "pack_key": key,
        "files": {ARRAYS_FILE: {"sha256": file_checksum(arrays_path),
                                "bytes": arrays_path.stat().st_size}},
        "layers": specs,
        "report": (report.to_json() if hasattr(report, "to_json")
                   else report),
        "meta": meta or {},
    }
    (tmp / MANIFEST_FILE).write_text(json.dumps(manifest, indent=1))
    try:
        os.replace(tmp, final)
    except OSError:                    # lost a concurrent-writer race
        shutil.rmtree(tmp, ignore_errors=True)
    log.info("published artifact %s (%d layer(s), %.2f MiB)", final,
             len(layers), sum(a.nbytes for a in arrays.values()) / 2**20)
    return final


def load_artifact(artifact_dir, key):
    """Load + verify the artifact for ``key``.

    Verification order: digest directory exists -> manifest readable ->
    format version -> manifest pack key matches -> per-file byte size and
    sha256 -> per-leaf presence/shape against the spec -> full layout
    validation (``core.validate``).  Raises the matching
    ``ArtifactError`` subclass (or ``LayoutError``) at the first failure;
    returns ``(layers, report)`` where ``layers`` maps layer node paths
    to validated layouts.
    """
    artifact_dir = pathlib.Path(artifact_dir)
    d = artifact_dir / key
    if not d.is_dir():
        stale = [p.name for p in artifact_dir.glob("*")
                 if p.is_dir() and not p.name.startswith(".tmp")] \
            if artifact_dir.is_dir() else []
        hint = (f" ({len(stale)} artifact(s) with other digests present "
                "— stale after a weight/mapping change?)") if stale else ""
        raise ArtifactMissing(f"no artifact for digest {key}{hint}", path=d)
    man_path = d / MANIFEST_FILE
    if not man_path.exists():
        raise ArtifactCorrupt("manifest missing (torn write?)", path=d)
    try:
        manifest = json.loads(man_path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ArtifactCorrupt(f"unreadable manifest: {e}",
                              path=man_path) from e
    ver = manifest.get("format_version")
    if ver != FORMAT_VERSION:
        raise ArtifactVersionSkew(
            f"artifact format_version {ver!r} != supported "
            f"{FORMAT_VERSION}", path=man_path)
    if manifest.get("pack_key") != key:
        raise ArtifactDigestMismatch(
            f"manifest pack_key {manifest.get('pack_key')!r} != requested "
            f"digest {key!r}", path=man_path)
    for fname, rec in manifest.get("files", {}).items():
        fp = d / fname
        if not fp.exists():
            raise ArtifactChecksumError(f"payload file {fname} missing",
                                        path=fp)
        size = fp.stat().st_size
        if size != rec.get("bytes"):
            raise ArtifactChecksumError(
                f"{fname} is {size} bytes, manifest says "
                f"{rec.get('bytes')} (truncated write?)", path=fp)
        digest = file_checksum(fp)
        if digest != rec.get("sha256"):
            raise ArtifactChecksumError(
                f"{fname} sha256 {digest[:12]}... != manifest "
                f"{str(rec.get('sha256'))[:12]}... (bit corruption?)",
                path=fp)
    try:
        data = np.load(d / ARRAYS_FILE)
    except Exception as e:  # zipfile/pickle errors vary by corruption
        raise ArtifactCorrupt(f"unreadable arrays bundle: {e}",
                              path=d / ARRAYS_FILE) from e
    try:
        layer_specs = manifest["layers"]
        report = manifest["report"]
    except KeyError as e:
        raise ArtifactCorrupt(f"manifest missing section {e}",
                              path=man_path) from e
    layers = {}
    for lpath, spec in layer_specs.items():
        layout = _layout_from_spec(lpath, spec, data)
        validate_layout(layout, path=lpath)     # LayoutError propagates
        layers[lpath] = layout
    # rebuild the typed report (also accepts historical bare-list rows)
    from repro.serve.compile import CompileReport
    report = CompileReport.from_json(report)
    return layers, report


def _copy_dicts(tree):
    """Copy the dict skeleton (leaves shared) so grafting never mutates
    the caller's param tree."""
    return {k: _copy_dicts(v) if isinstance(v, dict) else v
            for k, v in tree.items()}


def load_grafted(artifact_dir, key, params, *, keep_dense=True):
    """The warm-start front door behind ``compile_model(artifact_dir=)``.

    Returns ``(exec_params, report)`` with the stored layouts grafted
    onto ``params`` (dense ``w`` dropped when ``keep_dense`` is False —
    the same semantics as a fresh compile), or ``None`` after logging the
    structured fallback reason — the caller then packs fresh.  No failure
    mode escapes: corruption can cost a repack, never a wrong output.
    """
    try:
        layers, report = load_artifact(artifact_dir, key)
        exec_params = _copy_dicts(params)
        for lpath, layout in layers.items():
            node = _resolve(exec_params, lpath)
            node["packed"] = layout
            if not keep_dense:
                node.pop("w", None)
    except (ArtifactError, LayoutError, KeyError, TypeError) as e:
        code = getattr(e, "code", type(e).__name__)
        level = log.info if isinstance(e, ArtifactMissing) else log.warning
        level("artifact fallback -> fresh pack [%s]: %s", code, e)
        return None
    log.info("warm start: %d packed layer(s) from %s", len(layers),
             pathlib.Path(artifact_dir) / key)
    return exec_params, report
