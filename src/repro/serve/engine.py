"""Serving: prefill (forward pass that also emits the per-layer caches) and
the batched decode loop.  ``decode_step`` itself lives in models/transformer
(it is what the decode_* dry-run shapes lower)."""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import transformer as T

tmap = jax.tree_util.tree_map


def _window_kv(k, v, S_len, window):
    if window and window < S_len:
        k = k[:, S_len - window:]
        v = v[:, S_len - window:]
        pos = jnp.arange(S_len - window, S_len, dtype=jnp.int32)
    else:
        pos = jnp.arange(S_len, dtype=jnp.int32)
    return k, v, pos


def prefill(params, cfg: ArchConfig, tokens, frontend=None, dist=None):
    """tokens (B,S) -> (last-token logits (B,1,V), cache matching init_cache)."""
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = dist.shard_activations(x)
    fam = cfg.family
    W = cfg.sliding_window

    if fam in ("dense", "moe", "hybrid"):
        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, fam, dist=dist,
                                   collect_cache=True)
            k, v, pos = _window_kv(c["k"], c["v"], Sq, W)
            out_c = {"k": k, "v": v, "pos": pos}
            if fam == "hybrid":
                hn = L.rmsnorm(lp["ln1"], h)  # recompute state cheaply
                _, st = S.ssm(lp["ssm"], hn, dist=dist)
                out_c = (out_c, st)
            return (h,), out_c
        (x,), caches = T.maybe_scan(body, (x,), params["layers"],
                                    cfg.unroll_layers)
        if fam == "hybrid":
            cache = {"kv": caches[0], "ssm": caches[1]}
        else:
            cache = {"kv": caches}
    elif fam == "ssm":
        def body(carry, lp):
            h, = carry
            hn = L.rmsnorm(lp["ln1"], h)
            out, st = S.ssm(lp["ssm"], hn, dist=dist)
            return (h + out,), st
        (x,), st = T.maybe_scan(body, (x,), params["layers"],
                                cfg.unroll_layers)
        cache = {"ssm": st}
    elif fam == "encdec":
        # encode once; decoder prefill caches self-KV and cross-KV
        enc_pos = jnp.arange(frontend.shape[1], dtype=jnp.int32)

        def enc_body(carry, lp):
            h, = carry
            att, _ = A.mha(lp["attn"], L.rmsnorm(lp["ln1"], h), enc_pos,
                           cfg.n_heads, cfg.n_kv_heads, cfg.hd, causal=False,
                           dist=dist, shard=cfg.attn_shard)
            h = h + att
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return (h,), None
        (memory,), _ = T.maybe_scan(enc_body, (frontend.astype(x.dtype),),
                                    params["enc"], cfg.unroll_layers)
        memory = L.rmsnorm(params["norm_e"], memory)

        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, "xdec", dist=dist,
                                   memory=memory, collect_cache=True)
            pos = jnp.arange(Sq, dtype=jnp.int32)
            return (h,), ({"k": c["k"], "v": c["v"], "pos": pos},
                          c["xk"], c["xv"])
        (x,), (kv, xk, xv) = T.maybe_scan(body, (x,), params["dec"],
                                          cfg.unroll_layers)
        cache = {"kv": kv, "xk": xk, "xv": xv}
    elif fam == "vlm":
        memory = frontend.astype(x.dtype)
        k = cfg.cross_attn_interval

        def group_body(carry, gp):
            h, = carry

            def self_body(hc, lp):
                hh, = hc
                hh, _, c = T._layer_fwd(lp, hh, positions, cfg, "dense",
                                        dist=dist, collect_cache=True)
                return (hh,), {"k": c["k"], "v": c["v"],
                               "pos": jnp.arange(Sq, dtype=jnp.int32)}
            (h,), kv_self = T.maybe_scan(self_body, (h,), gp["selfs"],
                                         cfg.unroll_layers)
            hn = L.rmsnorm(gp["cross"]["ln1"], h)
            xa, xkv = A.mha(gp["cross"]["xattn"], hn, positions, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd, dist=dist,
                            shard=cfg.attn_shard, memory=memory)
            h = h + jnp.tanh(gp["cross"]["gate"]).astype(h.dtype) * xa
            h = h + L.ffn(gp["cross"]["ffn"], L.rmsnorm(gp["cross"]["ln2"], h))
            return (h,), (kv_self, xkv[0], xkv[1])
        (x,), (kv_self, xk, xv) = T.maybe_scan(group_body, (x,),
                                               params["groups"],
                                               cfg.unroll_layers)
        n_groups = cfg.n_layers // k
        kv_self = tmap(lambda a: a.reshape((n_groups * (k - 1),) + a.shape[2:]),
                       kv_self)
        cache = {"kv_self": kv_self, "xk": xk, "xv": xv}
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["norm_f"], x[:, -1:, :])
    logits = L.unembed(params["head"], x)
    return logits, cache


# jitted closures are cached per call signature: a fresh jax.jit(lambda ...)
# every generate() would re-trace + re-compile the whole model per request.
# cfg is a frozen (hashable) dataclass; dist objects are keyed by identity.
# LRU-bounded — each entry pins a full compiled executable, so an unbounded
# dict would grow with every distinct (cfg, n_new, temperature) seen.
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 32


def _cached_jit(key, make):
    if key in _JIT_CACHE:
        _JIT_CACHE.move_to_end(key)
    else:
        _JIT_CACHE[key] = jax.jit(make())
        while len(_JIT_CACHE) > _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
    return _JIT_CACHE[key]


def _jit_prefill(cfg, dist):
    return _cached_jit(
        ("prefill", cfg, id(dist)),
        lambda: lambda p, t, f: prefill(p, cfg, t, frontend=f, dist=dist))


def _jit_decode_loop(cfg, n_new, temperature, dist):
    return _cached_jit(
        ("loop", cfg, n_new, temperature, id(dist)),
        lambda: lambda p, t, c, s, k: T.decode_loop(
            p, cfg, t, c, s, n_new, temperature=temperature, key=k,
            dist=dist))


def _jit_decode_step(cfg, dist):
    return _cached_jit(
        ("step", cfg, id(dist)),
        lambda: lambda p, tok, c, pos: T.decode_step(p, cfg, tok, c, pos,
                                                     dist=dist))


def generate(params, cfg: ArchConfig, tokens, n_new, frontend=None,
             dist=None, temperature=0.0, key=None):
    """Fused generation: jitted prefill, then ONE compiled scan over
    ``decode_step`` (``models.transformer.decode_loop``) — decoding never
    round-trips through Python per token.  Works with dense, masked, and
    ``compile_model``-packed params alike."""
    B, Sq = tokens.shape
    logits, cache = _jit_prefill(cfg, dist)(params, tokens, frontend)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    start = jnp.full((B, 1), Sq, jnp.int32)
    loop = _jit_decode_loop(cfg, n_new, temperature, dist)
    toks, _ = loop(params, tok, cache, start,
                   key if key is not None else jax.random.PRNGKey(0))
    return toks


def generate_python(params, cfg: ArchConfig, tokens, n_new, frontend=None,
                    dist=None, temperature=0.0, key=None):
    """Reference eager loop over jitted decode_step (one dispatch + one
    device sync per token).  Kept as the parity oracle for the fused scan
    loop and for step-by-step debugging."""
    B, Sq = tokens.shape
    logits, cache = _jit_prefill(cfg, dist)(params, tokens, frontend)
    step_fn = _jit_decode_step(cfg, dist)
    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    for i in range(n_new):
        out.append(tok)
        pos = jnp.full((B, 1), Sq + i, jnp.int32)
        logits, cache = step_fn(params, tok, cache, pos)
        if temperature > 0:
            sub = jax.random.fold_in(key, i)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
