"""Serving: prefill (forward pass that also emits the per-layer caches) and
the batched decode loop.  ``decode_step`` itself lives in models/transformer
(it is what the decode_* dry-run shapes lower)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import transformer as T

tmap = jax.tree_util.tree_map


def _window_kv(k, v, S_len, window):
    if window and window < S_len:
        k = k[:, S_len - window:]
        v = v[:, S_len - window:]
        pos = jnp.arange(S_len - window, S_len, dtype=jnp.int32)
    else:
        pos = jnp.arange(S_len, dtype=jnp.int32)
    return k, v, pos


def prefill(params, cfg: ArchConfig, tokens, frontend=None, dist=None):
    """tokens (B,S) -> (last-token logits (B,1,V), cache matching init_cache)."""
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = dist.shard_activations(x)
    fam = cfg.family
    W = cfg.sliding_window

    if fam in ("dense", "moe", "hybrid"):
        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, fam, dist=dist,
                                   collect_cache=True)
            k, v, pos = _window_kv(c["k"], c["v"], Sq, W)
            out_c = {"k": k, "v": v, "pos": pos}
            if fam == "hybrid":
                hn = L.rmsnorm(lp["ln1"], h)  # recompute state cheaply
                _, st = S.ssm(lp["ssm"], hn, dist=dist)
                out_c = (out_c, st)
            return (h,), out_c
        (x,), caches = T.maybe_scan(body, (x,), params["layers"],
                                    cfg.unroll_layers)
        if fam == "hybrid":
            cache = {"kv": caches[0], "ssm": caches[1]}
        else:
            cache = {"kv": caches}
    elif fam == "ssm":
        def body(carry, lp):
            h, = carry
            hn = L.rmsnorm(lp["ln1"], h)
            out, st = S.ssm(lp["ssm"], hn, dist=dist)
            return (h + out,), st
        (x,), st = T.maybe_scan(body, (x,), params["layers"],
                                cfg.unroll_layers)
        cache = {"ssm": st}
    elif fam == "encdec":
        # encode once; decoder prefill caches self-KV and cross-KV
        enc_pos = jnp.arange(frontend.shape[1], dtype=jnp.int32)

        def enc_body(carry, lp):
            h, = carry
            att, _ = A.mha(lp["attn"], L.rmsnorm(lp["ln1"], h), enc_pos,
                           cfg.n_heads, cfg.n_kv_heads, cfg.hd, causal=False,
                           dist=dist, shard=cfg.attn_shard)
            h = h + att
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return (h,), None
        (memory,), _ = T.maybe_scan(enc_body, (frontend.astype(x.dtype),),
                                    params["enc"], cfg.unroll_layers)
        memory = L.rmsnorm(params["norm_e"], memory)

        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, "xdec", dist=dist,
                                   memory=memory, collect_cache=True)
            pos = jnp.arange(Sq, dtype=jnp.int32)
            return (h,), ({"k": c["k"], "v": c["v"], "pos": pos},
                          c["xk"], c["xv"])
        (x,), (kv, xk, xv) = T.maybe_scan(body, (x,), params["dec"],
                                          cfg.unroll_layers)
        cache = {"kv": kv, "xk": xk, "xv": xv}
    elif fam == "vlm":
        memory = frontend.astype(x.dtype)
        k = cfg.cross_attn_interval

        def group_body(carry, gp):
            h, = carry

            def self_body(hc, lp):
                hh, = hc
                hh, _, c = T._layer_fwd(lp, hh, positions, cfg, "dense",
                                        dist=dist, collect_cache=True)
                return (hh,), {"k": c["k"], "v": c["v"],
                               "pos": jnp.arange(Sq, dtype=jnp.int32)}
            (h,), kv_self = T.maybe_scan(self_body, (h,), gp["selfs"],
                                         cfg.unroll_layers)
            hn = L.rmsnorm(gp["cross"]["ln1"], h)
            xa, xkv = A.mha(gp["cross"]["xattn"], hn, positions, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd, dist=dist,
                            shard=cfg.attn_shard, memory=memory)
            h = h + jnp.tanh(gp["cross"]["gate"]).astype(h.dtype) * xa
            h = h + L.ffn(gp["cross"]["ffn"], L.rmsnorm(gp["cross"]["ln2"], h))
            return (h,), (kv_self, xkv[0], xkv[1])
        (x,), (kv_self, xk, xv) = T.maybe_scan(group_body, (x,),
                                               params["groups"],
                                               cfg.unroll_layers)
        n_groups = cfg.n_layers // k
        kv_self = tmap(lambda a: a.reshape((n_groups * (k - 1),) + a.shape[2:]),
                       kv_self)
        cache = {"kv_self": kv_self, "xk": xk, "xv": xv}
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["norm_f"], x[:, -1:, :])
    logits = L.unembed(params["head"], x)
    return logits, cache


def generate(params, cfg: ArchConfig, tokens, n_new, frontend=None,
             dist=None, temperature=0.0, key=None):
    """Greedy/temperature sampling loop over jitted decode_step."""
    B, Sq = tokens.shape
    logits, cache = jax.jit(
        lambda p, t, f: prefill(p, cfg, t, frontend=f, dist=dist)
    )(params, tokens, frontend)
    step_fn = jax.jit(
        lambda p, tok, c, pos: T.decode_step(p, cfg, tok, c, pos, dist=dist))
    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for i in range(n_new):
        out.append(tok)
        pos = jnp.full((B, 1), Sq + i, jnp.int32)
        logits, cache = step_fn(params, tok, cache, pos)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
