"""Serving: prefill (forward pass that also emits the per-layer caches),
the single-sequence fused decode loop (``generate`` — the bit-identity
oracle), and the continuous-batching ``ServingEngine`` that decodes many
live requests through ONE batched step per token so every packed kernel
launch amortizes the streamed weights over the whole batch.
``decode_step`` itself lives in models/transformer (it is what the
decode_* dry-run shapes lower)."""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import transformer as T
from repro.serve import kvcache as KV
from repro.serve.scheduler import (REASON_DEADLINE_EXPIRED, REASON_OVER_BUDGET,
                                   REASON_QUARANTINED, Request, Scheduler)

tmap = jax.tree_util.tree_map


def _window_kv(k, v, S_len, window):
    if window and window < S_len:
        k = k[:, S_len - window:]
        v = v[:, S_len - window:]
        pos = jnp.arange(S_len - window, S_len, dtype=jnp.int32)
    else:
        pos = jnp.arange(S_len, dtype=jnp.int32)
    return k, v, pos


def prefill(params, cfg: ArchConfig, tokens, frontend=None, dist=None):
    """tokens (B,S) -> (last-token logits (B,1,V), cache matching init_cache)."""
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = dist.shard_activations(x)
    fam = cfg.family
    W = cfg.sliding_window

    if fam in ("dense", "moe", "hybrid"):
        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, fam, dist=dist,
                                   collect_cache=True)
            k, v, pos = _window_kv(c["k"], c["v"], Sq, W)
            out_c = {"k": k, "v": v, "pos": pos}
            if fam == "hybrid":
                hn = L.rmsnorm(lp["ln1"], h)  # recompute state cheaply
                _, st = S.ssm(lp["ssm"], hn, dist=dist)
                out_c = (out_c, st)
            return (h,), out_c
        (x,), caches = T.maybe_scan(body, (x,), params["layers"],
                                    cfg.unroll_layers)
        if fam == "hybrid":
            cache = {"kv": caches[0], "ssm": caches[1]}
        else:
            cache = {"kv": caches}
    elif fam == "ssm":
        def body(carry, lp):
            h, = carry
            hn = L.rmsnorm(lp["ln1"], h)
            out, st = S.ssm(lp["ssm"], hn, dist=dist)
            return (h + out,), st
        (x,), st = T.maybe_scan(body, (x,), params["layers"],
                                cfg.unroll_layers)
        cache = {"ssm": st}
    elif fam == "encdec":
        # encode once; decoder prefill caches self-KV and cross-KV
        enc_pos = jnp.arange(frontend.shape[1], dtype=jnp.int32)

        def enc_body(carry, lp):
            h, = carry
            att, _ = A.mha(lp["attn"], L.rmsnorm(lp["ln1"], h), enc_pos,
                           cfg.n_heads, cfg.n_kv_heads, cfg.hd, causal=False,
                           dist=dist, shard=cfg.attn_shard)
            h = h + att
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return (h,), None
        (memory,), _ = T.maybe_scan(enc_body, (frontend.astype(x.dtype),),
                                    params["enc"], cfg.unroll_layers)
        memory = L.rmsnorm(params["norm_e"], memory)

        def body(carry, lp):
            h, = carry
            h, _, c = T._layer_fwd(lp, h, positions, cfg, "xdec", dist=dist,
                                   memory=memory, collect_cache=True)
            pos = jnp.arange(Sq, dtype=jnp.int32)
            return (h,), ({"k": c["k"], "v": c["v"], "pos": pos},
                          c["xk"], c["xv"])
        (x,), (kv, xk, xv) = T.maybe_scan(body, (x,), params["dec"],
                                          cfg.unroll_layers)
        cache = {"kv": kv, "xk": xk, "xv": xv}
    elif fam == "vlm":
        memory = frontend.astype(x.dtype)
        k = cfg.cross_attn_interval

        def group_body(carry, gp):
            h, = carry

            def self_body(hc, lp):
                hh, = hc
                hh, _, c = T._layer_fwd(lp, hh, positions, cfg, "dense",
                                        dist=dist, collect_cache=True)
                return (hh,), {"k": c["k"], "v": c["v"],
                               "pos": jnp.arange(Sq, dtype=jnp.int32)}
            (h,), kv_self = T.maybe_scan(self_body, (h,), gp["selfs"],
                                         cfg.unroll_layers)
            hn = L.rmsnorm(gp["cross"]["ln1"], h)
            xa, xkv = A.mha(gp["cross"]["xattn"], hn, positions, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd, dist=dist,
                            shard=cfg.attn_shard, memory=memory)
            h = h + jnp.tanh(gp["cross"]["gate"]).astype(h.dtype) * xa
            h = h + L.ffn(gp["cross"]["ffn"], L.rmsnorm(gp["cross"]["ln2"], h))
            return (h,), (kv_self, xkv[0], xkv[1])
        (x,), (kv_self, xk, xv) = T.maybe_scan(group_body, (x,),
                                               params["groups"],
                                               cfg.unroll_layers)
        n_groups = cfg.n_layers // k
        kv_self = tmap(lambda a: a.reshape((n_groups * (k - 1),) + a.shape[2:]),
                       kv_self)
        cache = {"kv_self": kv_self, "xk": xk, "xv": xv}
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["norm_f"], x[:, -1:, :])
    logits = L.unembed(params["head"], x)
    return logits, cache


# jitted closures are cached per call signature: a fresh jax.jit(lambda ...)
# every generate() would re-trace + re-compile the whole model per request.
# cfg is a frozen (hashable) dataclass; dist objects are keyed by identity.
# LRU-bounded — each entry pins a full compiled executable, so an unbounded
# dict would grow with every distinct (cfg, n_new, temperature) seen.
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 32


def _cached_jit(key, make):
    if key in _JIT_CACHE:
        _JIT_CACHE.move_to_end(key)
    else:
        _JIT_CACHE[key] = jax.jit(make())
        while len(_JIT_CACHE) > _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
    return _JIT_CACHE[key]


def _jit_prefill(cfg, dist):
    return _cached_jit(
        ("prefill", cfg, id(dist)),
        lambda: lambda p, t, f: prefill(p, cfg, t, frontend=f, dist=dist))


def _jit_decode_loop(cfg, n_new, temperature, dist):
    return _cached_jit(
        ("loop", cfg, n_new, temperature, id(dist)),
        lambda: lambda p, t, c, s, k: T.decode_loop(
            p, cfg, t, c, s, n_new, temperature=temperature, key=k,
            dist=dist))


def _jit_decode_step(cfg, dist):
    return _cached_jit(
        ("step", cfg, id(dist)),
        lambda: lambda p, tok, c, pos: T.decode_step(p, cfg, tok, c, pos,
                                                     dist=dist))


def generate(params, cfg: ArchConfig, tokens, n_new, frontend=None,
             dist=None, temperature=0.0, key=None):
    """Fused generation: jitted prefill, then ONE compiled scan over
    ``decode_step`` (``models.transformer.decode_loop``) — decoding never
    round-trips through Python per token.  Works with dense, masked, and
    ``compile_model``-packed params alike."""
    B, Sq = tokens.shape
    logits, cache = _jit_prefill(cfg, dist)(params, tokens, frontend)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    start = jnp.full((B, 1), Sq, jnp.int32)
    loop = _jit_decode_loop(cfg, n_new, temperature, dist)
    toks, _ = loop(params, tok, cache, start,
                   key if key is not None else jax.random.PRNGKey(0))
    return toks


def _jit_serving_step(cfg, dist):
    """The engine's batched decode executable: ragged decode step + greedy
    argmax + per-slot finite check fused into one program.  Cached per
    (cfg, dist); the slot-array shapes are fixed for an engine's lifetime,
    so admission/eviction never retraces (locked by a trace-count
    regression test).

    The finite flag (``ok``, one bool per slot) is the numerical
    quarantine probe: it reduces THIS slot's logits only, inside the same
    launch — no extra dispatch, no retrace — so the harvest loop can evict
    a poisoned slot before its garbage argmax ever becomes a token."""
    def make():
        def step(p, tok, cache, pos, cap):
            logits, cache = T.decode_step_ragged(p, cfg, tok, cache, pos,
                                                 cap, dist=dist)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            ok = jnp.all(jnp.isfinite(logits[:, -1, :].astype(jnp.float32)),
                         axis=-1)
            return nxt, ok, cache
        return step
    return _cached_jit(("serving_step", cfg, id(dist)), make)


class ServingEngine:
    """Continuous-batching serving engine: scheduler + slot KV cache +
    one batched decode launch per step.

    Requests are admitted into free slots mid-flight (each admission is a
    B=1 jitted prefill plus a slot-row write), every step runs ALL active
    slots through one ``decode_step_ragged`` — so each degree-bin
    ``bsr_matmul_packed`` launch does an M=B GEMM over the same packed
    weights instead of B separate M=1 GEMVs — and finished requests are
    evicted the step their stop condition fires, freeing the slot for the
    queue.  Decoding is greedy (temperature 0): a batch of N requests is
    token-for-token identical to N independent ``generate`` calls (the
    oracle test in tests/test_serving.py).

    Fault tolerance: ``validate=True`` (default) runs
    ``serve.compile.degrade_invalid_layers`` over the exec params at
    construction — any packed layout failing ``core.validate`` is retired
    to the masked-dense ``DegradedLayer`` path (slower, never wrong) and
    counted in ``stats["degraded_layers"]``.  Every step, queue TTLs and
    running deadlines are swept BEFORE admission, and the batched decode's
    fused per-slot finite probe quarantines any slot whose logits went
    non-finite — the slot is evicted (status ``"quarantined"``) without
    emitting the garbage token, and the surviving slots' tokens are
    bit-identical to a run where the poisoned request was never admitted
    (slots share weights, never activations — locked by the chaos suite).

    Counters in ``stats``: engine steps, admitted/finished/evicted/
    rejected requests, quarantined slots, expired deadlines, degraded
    layers, emitted tokens, and the running occupancy sum
    (``mean_occupancy()`` = mean fraction of busy slots per step).
    """

    FAMILIES = ("dense", "moe", "ssm", "hybrid")

    def __init__(self, params, cfg: ArchConfig, *, n_slots=8, seq_cap=256,
                 dist=None, max_queue=None, validate=True, report=None):
        if cfg.family not in self.FAMILIES:
            raise NotImplementedError(
                f"family {cfg.family!r} is not served (supported: "
                f"{self.FAMILIES})")
        if cfg.sliding_window:
            # a slot never needs more ring than the attention window
            seq_cap = min(seq_cap, cfg.sliding_window)
        self.report = report
        degraded = []
        if validate:
            from repro.serve import compile as SC  # late: compile is heavy
            params, self.report, degraded = SC.degrade_invalid_layers(
                params, report=report)
        self.params, self.cfg, self.dist = params, cfg, dist
        self.n_slots, self.seq_cap = n_slots, seq_cap
        dtype = params["embed"]["table"].dtype
        self.cache = KV.init_slots(params, cfg, n_slots, seq_cap,
                                   dtype=dtype)
        self.sched = Scheduler(n_slots, max_queue=max_queue)
        # per-slot decode operands; free slots idle as pos=0/cap=1 padding
        self.tok = np.zeros((n_slots, 1), np.int32)
        self.pos = np.zeros((n_slots, 1), np.int32)
        self.cap = np.ones((n_slots,), np.int32)
        self._step_fn = _jit_serving_step(cfg, dist)
        self._rid = 0
        self.requests: dict = {}
        self.stats = {"steps": 0, "occupancy_sum": 0.0, "tokens": 0,
                      "admitted": 0, "finished": 0, "evicted": 0,
                      "rejected": 0, "quarantined": 0, "expired": 0,
                      "degraded_layers": len(degraded)}

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new_tokens, *, arrival=0,
               stop_token=None, deadline_steps=None, queue_ttl=None,
               retries=0, backoff=1) -> int:
        """Queue one request; returns its id (``requests[rid].tokens`` holds
        the output).  Prompts whose effective (window-clipped) length
        exceeds the slot capacity are rejected up front — the one budget a
        slot cannot ring-buffer away.

        ``deadline_steps`` / ``queue_ttl`` bound slot occupancy and queue
        wait (see ``serve.scheduler.Request``); ``retries`` / ``backoff``
        bound the resubmission policy when the scheduler's ``max_queue``
        is full.
        """
        req = Request(self._rid, tuple(int(t) for t in prompt),
                      int(max_new_tokens), arrival=arrival,
                      stop_token=stop_token, deadline_steps=deadline_steps,
                      queue_ttl=queue_ttl, retries=retries, backoff=backoff)
        self._rid += 1
        self.requests[req.rid] = req
        if (not req.prompt or req.max_new_tokens < 1
                or KV.slot_capacity(self.cfg, len(req.prompt))
                > self.seq_cap):
            self.sched.reject(req, REASON_OVER_BUDGET)
            self.stats["rejected"] += 1
        else:
            if self.sched.submit(req, self.stats["steps"]) == "rejected":
                self.stats["rejected"] += 1
        return req.rid

    # -- engine loop --------------------------------------------------------

    def _admit(self):
        while (pair := self.sched.admit(self.stats["steps"])) is not None:
            slot, req = pair
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
            logits, rc = _jit_prefill(self.cfg, self.dist)(
                self.params, toks, None)
            t0 = int(jnp.argmax(logits[:, -1, :], axis=-1)[0])
            req.tokens.append(t0)
            self.stats["admitted"] += 1
            self.stats["tokens"] += 1
            if req.done():      # budget of 1 (or instant stop token)
                self._release(slot, req, "finished")
                continue
            self.cache = KV.write_prefill(self.cache, slot, rc)
            self.cap[slot] = KV.slot_capacity(self.cfg, len(req.prompt))
            self.pos[slot] = len(req.prompt)
            self.tok[slot] = t0

    def _release(self, slot, req, status, reason=None):
        self.sched.release(req, status, reason)
        self.cache = KV.clear_slot(self.cache, slot)
        self.tok[slot], self.pos[slot], self.cap[slot] = 0, 0, 1
        if status == "finished":
            self.stats["finished"] += 1
        elif status == "quarantined":
            self.stats["quarantined"] += 1
        else:
            self.stats["evicted"] += 1

    def _sweep_faults(self):
        """Top-of-step fault pass, all BEFORE admission so freed slots
        refill the same step (bounded recovery): expire overdue queue
        TTLs, re-submit due retry backoffs, evict running requests past
        their ``deadline_steps`` budget."""
        now = self.stats["steps"]
        self.stats["expired"] += len(self.sched.expire(now))
        self.stats["rejected"] += len(self.sched.poll_retries(now))
        for slot, req in self.sched.active():
            if (req.deadline_steps is not None
                    and req.admitted_at is not None
                    and now - req.admitted_at >= req.deadline_steps):
                self._release(slot, req, "evicted",
                              reason=REASON_DEADLINE_EXPIRED)
                self.stats["expired"] += 1

    def step(self) -> int:
        """One engine step: sweep deadlines/TTLs/retries, admit from the
        queue into free slots, decode every active slot in one batched
        launch, harvest tokens, evict finished requests, and quarantine
        any slot whose logits came back non-finite (its garbage argmax is
        never appended; neighbors are untouched).  Returns the number of
        active slots stepped (0 = an idle tick while the open-loop queue
        waits to arrive)."""
        self._sweep_faults()
        self._admit()
        active = self.sched.active()
        self.stats["steps"] += 1
        self.stats["occupancy_sum"] += len(active) / self.n_slots
        if not active:
            return 0
        nxt, ok, self.cache = self._step_fn(
            self.params, jnp.asarray(self.tok), self.cache,
            jnp.asarray(self.pos), jnp.asarray(self.cap))
        nxt, ok = np.asarray(nxt), np.asarray(ok)
        for slot, req in active:
            if not bool(ok[slot]):
                self._release(slot, req, "quarantined",
                              reason=REASON_QUARANTINED)
                continue
            t = int(nxt[slot])
            req.tokens.append(t)
            self.stats["tokens"] += 1
            self.pos[slot] += 1
            self.tok[slot] = t
            if req.done():
                self._release(slot, req, "finished")
        return len(active)

    def run(self, max_steps=100_000):
        """Drive ``step`` until queue and slots drain; returns ``stats``.
        ``max_steps`` bounds runaway workloads — anything still live when
        it trips is evicted (status ``"evicted"``), never silently lost."""
        while self.sched.has_work() and self.stats["steps"] < max_steps:
            self.step()
        for slot, req in self.sched.active():
            self._release(slot, req, "evicted")
        return self.stats

    def mean_occupancy(self) -> float:
        """Mean fraction of busy slots per engine step so far."""
        steps = self.stats["steps"]
        return self.stats["occupancy_sum"] / steps if steps else 0.0


def generate_python(params, cfg: ArchConfig, tokens, n_new, frontend=None,
                    dist=None, temperature=0.0, key=None):
    """Reference eager loop over jitted decode_step (one dispatch + one
    device sync per token).  Kept as the parity oracle for the fused scan
    loop and for step-by-step debugging."""
    B, Sq = tokens.shape
    logits, cache = _jit_prefill(cfg, dist)(params, tokens, frontend)
    step_fn = _jit_decode_step(cfg, dist)
    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    for i in range(n_new):
        out.append(tok)
        pos = jnp.full((B, 1), Sq + i, jnp.int32)
        logits, cache = step_fn(params, tok, cache, pos)
        if temperature > 0:
            sub = jax.random.fold_in(key, i)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
