"""Model "codegen" (paper §4.3): pruning masks + per-layer scheme mapping
-> packed execution params.

``compile_model`` is the compiler step the paper describes between pruning
and deployment: given trained params, the {0,1} mask tree, and the
per-layer scheme mapping produced by ``core.mapper_rule``/``mapper_search``,
it packs every block-pruned layer into a ``core.packed.PackedLayout``
— the single interchange format shared by every sparse consumer — and
installs it as ``params[...]["packed"]`` so ``models.layers.linear``
(attention qkv/out, FFN gate/up/down, SSM in/out projections), the batched
MoE expert path in ``models.moe``, and the conv path in
``models.convnet``/``kernels.ops.sparse_conv2d`` dispatch through the
Pallas block-sparse kernel — PatDNN-style sparsity baked into the executed
code, adapted to TPU tiles.

Layer kinds are detected structurally (``_layer_kind``): block-punched
4-D (P, Q, Kh, Kw) conv weights are im2col-lowered before packing
(``core.bcs.conv_lower``), pattern/connectivity 4-D conv masks are
tap-lowered into a ``core.packed.TapLayout`` (``core.bcs.pattern_lower``)
for the Pallas tap-gather kernel — a pattern pick no longer falls back to
masked-dense — depthwise convs are skipped with a logged reason (§5.2.4),
and everything else packs as a (possibly stacked) GEMM.

Row reordering for load balance (Fig 4) happens here by default
(``reorder=True``): block columns are degree-sorted and binned before
padding, so the executed column degree drops from the max toward the mean
(the report carries ``L`` -> ``L_reordered`` and the gain per layer).

Layer stacks are scanned over a stacked layer axis (MoE expert weights add
an expert axis), so per-layer layouts are padded to common per-bin column
degrees and stacked — one pallas_call per projection *kind* and bin, not
per layer.  Packing itself is vectorized + content-cached (see
``kernels.ops.pack``); a second compile of the same weights is free.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import reweighted as RW
from repro.core.packed import PackedLayout
from repro.kernels import ops

# schemes the sparse executors can exploit: FC block schemes pack the
# weight as-is; block_punched (the paper's CONV scheme) packs the
# im2col-lowered weight into whole dead BCS blocks; pattern (incl.
# connectivity pruning) carries no block structure and tap-lowers into a
# TapLayout for the tap-gather kernel — see _layer_kind below.
BLOCK_SCHEMES = ("block", "block_row", "block_col")
CONV_SCHEMES = ("block_punched",)
PATTERN_SCHEMES = ("pattern",)
PACKABLE_SCHEMES = BLOCK_SCHEMES + CONV_SCHEMES + PATTERN_SCHEMES


def _layer_kind(w, scheme: str) -> str:
    """Structural layer-kind detection — what decides the layout producer,
    instead of path-name heuristics:

      conv         : 4-D (P, Q, Kh, Kw) weight mapped to a CONV block
                     scheme -> im2col BCS producer
      pattern_conv : 4-D conv weight mapped to the pattern scheme ->
                     tap-gather producer (per-kernel pattern masks carry no
                     block structure, so the skippable unit is a tap)
      depthwise    : conv with Q == 1 (never packed, §5.2.4)
      linear       : trailing (K, N) GEMM weight, any leading stack dims
                     (scanned layers, MoE experts, or both)

    The mapped scheme disambiguates rank-4 weights: a stacked MoE expert
    weight (L, E, K, N) is also 4-D, but the mapper only ever assigns
    ``block_punched``/``pattern`` to real conv weights (their groups are
    kernel positions), so scheme + rank identifies the producer."""
    if scheme in CONV_SCHEMES + PATTERN_SCHEMES:
        if getattr(w, "ndim", 0) != 4:
            return "bad_conv"
        if w.shape[1] == 1:
            return "depthwise"
        return "pattern_conv" if scheme in PATTERN_SCHEMES else "conv"
    return "linear"


def _stack_pad_L(arrays, Lb):
    """Stack per-slice bin arrays after zero-padding axis 1 (the column
    degree) to ``Lb`` — padding slots keep k_idx 0 / zero values."""
    out = []
    for a in arrays:
        a = np.asarray(a)
        pad = Lb - a.shape[1]
        if pad:
            a = np.concatenate(
                [a, np.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)], 1)
        out.append(a)
    return np.stack(out)


def _pack_stacked(w, mask, block, *, reorder=True, n_bins=4):
    """Pack (..., K, N) weights slice-by-slice, pad every slice's per-bin
    column degree to the stack max, and restack -> a scan/vmap-compatible
    ``PackedLayout`` whose leaves carry the leading stack dims (layers,
    experts, or both).

    Returns (PackedLayout, stats)."""
    w = np.asarray(w)
    mask = np.broadcast_to(np.asarray(mask), w.shape)
    lead = w.shape[:-2]
    K, N = w.shape[-2:]
    bk, bn = block
    Kb = K // bk
    wf = w.reshape((-1, K, N))
    mf = mask.reshape((-1, K, N))
    layouts = [ops.pack(wf[i], mf[i], block, reorder=reorder, n_bins=n_bins)
               for i in range(wf.shape[0])]
    nb = layouts[0].n_bins                    # identical across slices
    values, k_idx = [], []
    for b in range(nb):
        Lb = max(l.bin_degrees[b] for l in layouts)
        values.append(jnp.asarray(_stack_pad_L(
            [l.values[b] for l in layouts], Lb).reshape(
                lead + (-1, Lb, bk, bn))))
        k_idx.append(jnp.asarray(_stack_pad_L(
            [l.k_idx[b] for l in layouts], Lb).reshape(lead + (-1, Lb))))

    def restack(get):
        a = np.stack([np.asarray(get(l)) for l in layouts])
        return jnp.asarray(a.reshape(lead + a.shape[1:]))

    nnz = restack(lambda l: l.nnz)
    perm = restack(lambda l: l.perm) if reorder else None
    inv_perm = restack(lambda l: l.inv_perm) if reorder else None
    stacked = PackedLayout(values=tuple(values), k_idx=tuple(k_idx),
                           nnz=nnz, perm=perm, inv_perm=inv_perm,
                           block=tuple(block), shape=(K, N))
    # L: the padded max column degree (what every column pays without
    # reordering); L_reordered: mean executed degree under the binned
    # stacked layout.  Equal when reorder is off.
    L_pre = max(1, int(np.asarray(nnz).max()))
    L_eff = stacked.L_effective
    stats = {
        "block": tuple(block), "shape": (K, N), "L": L_pre, "Kb": Kb,
        "L_reordered": round(L_eff, 2),
        "reorder_gain": round(L_pre / max(L_eff, 1e-9), 2),
        "density": stacked.density,
        "flops_saved": stacked.flops_saved,
        "layers": int(np.prod(lead)) if lead else 1,
    }
    return stacked, stats


def compile_model(params, masks=None, mapping=(), *, block_override=None,
                  keep_dense=True, min_saving=0.0, reorder=True, n_bins=None,
                  exclude=("router", "embed", "head"), artifact_dir=None):
    """Pack every block-pruned linear/conv layer of ``params`` for sparse
    execution.  Returns (exec_params, report).

    params   : model param tree (nested dicts; linear nodes hold "w").
    masks    : {0,1} mask tree matching ``params`` (scalar sentinels on
               unpruned leaves, as built by ``reweighted.masks_for_spec``).
               None derives masks from the zeros already baked into ``w``
               (i.e. params after ``trainer.apply_masks``).
    mapping  : PruneSpec [(path_regex, SchemeChoice)] from the mapper —
               only paths mapped to a packable scheme are packed (FC block
               schemes pack the weight as-is; ``block_punched`` conv
               layers pack the im2col-lowered weight; ``pattern`` conv
               layers tap-lower into a TapLayout for the tap-gather
               kernel).
    block_override : force one (bk, bn) packing block for every layer
               (otherwise each layer uses its mapped choice.block).
    keep_dense : keep "w" next to "packed" (dense fallback / debugging);
               False drops it to halve serving weight memory.
    min_saving : skip packing when the effective skipped-FLOP fraction
               (1 - executed/(Kb*Nb) under the padded layout) is not above
               this — a padded layout with no skipping would only add
               gather overhead.
    reorder  : degree-sort + bin block columns before padding (paper Fig 4
               row reordering) so L drops toward the mean degree; outputs
               stay bit-identical (see ``core.bcs.pack_csc_reordered``).
    n_bins   : number of degree bins when reordering.  None (the default)
               uses each producer's own default: 4 for block layouts, 8
               for tap layouts (connectivity-bearing tap degrees spread
               wider — see ``kernels.ops.pack_taps``).
    exclude  : path substrings never packed (router/embeddings per §5.2.4).
               MoE expert projections (gate/up/down) ARE packed — they
               dispatch through ``kernels.ops.sparse_expert_linear``.
    artifact_dir : AOT artifact store (``serve.artifacts``).  When set,
               the model digest (weights + masks + mapping + compile
               knobs) is looked up first: digest match -> checksum verify
               -> layout validation -> warm start with the stored layouts
               grafted on (no packing at all).  Digest mismatch, checksum
               failure, version skew, or invariant violation logs its
               structured reason and falls back to THIS fresh pack, whose
               result is then published crash-safely (tmp + atomic
               rename) for the next start.

    Every packed node's report entry carries the effective density, the
    pre-reorder padded column degree L, the post-reorder ``L_reordered``
    with its gain, and the skipped-FLOP fraction; skipped nodes carry the
    reason, so the report doubles as the compile log.
    """
    artifact_key = None
    if artifact_dir is not None:
        from repro.serve import artifacts as ART
        artifact_key = ART.model_digest(
            params, masks, mapping, block_override=block_override,
            min_saving=min_saving, reorder=reorder, n_bins=n_bins,
            exclude=exclude)
        warm = ART.load_grafted(artifact_dir, artifact_key, params,
                                keep_dense=keep_dense)
        if warm is not None:
            return warm

    report = []
    # per-producer bin defaults (None = use each producer's own): block
    # layouts 4, tap layouts 8 — see kernels.ops.pack_taps
    gemm_bins = 4 if n_bins is None else n_bins
    tap_bins = 8 if n_bins is None else n_bins

    def walk(p, m, path):
        if not isinstance(p, dict):
            return p
        out = {k: walk(v, m.get(k) if isinstance(m, dict) else None,
                       f"{path}/{k}" if path else k)
               for k, v in p.items()}
        w = p.get("w")
        if w is None or isinstance(w, dict) or getattr(w, "ndim", 0) < 2:
            return out
        wpath = f"{path}/w" if path else "w"

        def skip(reason):
            report.append({"path": wpath, "packed": False, "reason": reason})
            return out

        if any(e in wpath for e in exclude):
            return skip("excluded")
        choice = RW.match(list(mapping), wpath)
        if choice is None or choice.scheme not in PACKABLE_SCHEMES:
            return skip("no block scheme mapped")
        kind = _layer_kind(w, choice.scheme)
        if kind == "depthwise":
            return skip("depthwise conv never packed (§5.2.4)")
        if kind == "bad_conv":
            return skip(f"{choice.scheme} needs a (P, Q, Kh, Kw) conv "
                        f"weight, got shape {tuple(w.shape)}")
        mask = m.get("w") if isinstance(m, dict) else None
        if masks is None:
            mask = np.asarray(w) != 0
        elif mask is None or getattr(mask, "ndim", 0) == 0:
            return skip("no mask (layer not pruned)")
        block = tuple(block_override or choice.block)
        if kind == "pattern_conv":
            # tap producer: pattern/connectivity masks carry no block
            # structure (every kernel keeps its own tap set), so the layer
            # lowers to per-filter tap lists over the im2col band and
            # executes through the tap-gather kernel — the scheme the
            # mapper picked for accuracy now runs sparsely instead of
            # silently falling back to masked-dense.
            tap = ops.pack_taps(w, mask, reorder=reorder, n_bins=tap_bins)
            P, Q, Kh, Kw = w.shape
            stats = {
                "block": (1, tap.group), "shape": tap.shape,
                "L": tap.L_max, "Kb": tap.shape[0],
                "L_reordered": round(tap.L_effective, 2),
                "reorder_gain": round(
                    tap.L_max / max(tap.L_effective, 1e-9), 2),
                "density": tap.density,
                "flops_saved": tap.flops_saved,
                "layers": 1,
                # implicit-GEMM accounting: patch bytes the materialized
                # path would allocate PER OUTPUT POSITION (total = B*Ho*Wo
                # of these), which the implicit tap kernel never touches
                "patch_b_per_pos": Kh * Kw * Q * w.dtype.itemsize,
            }
            packed = tap
        elif kind == "conv":
            # im2col producer: lower weight AND mask to the GEMM the conv
            # executes as (kernels.ops.sparse_conv2d), then reuse the one
            # packing pipeline.  The kernel-block choice (bp filters, bq
            # channels) becomes GEMM block (bq, bp) — see bcs.conv_lower.
            gemm_block, why = BCS.conv_gemm_block(block, w.shape)
            if gemm_block is None:
                return skip(why)
            P, Q, Kh, Kw = w.shape
            wl = BCS.conv_lower(w)
            ml = BCS.conv_lower(np.broadcast_to(np.asarray(mask), w.shape))
            packed, stats = _pack_stacked(wl, ml, gemm_block,
                                          reorder=reorder, n_bins=gemm_bins)
            # attach the static tap-offset table so the implicit-GEMM
            # kernel can gather from the feature map without a patch tensor
            packed = dataclasses.replace(
                packed,
                conv_taps=BCS.conv_tap_table(Kh, Kw, Q, gemm_block[0]))
            stats["patch_b_per_pos"] = Kh * Kw * Q * w.dtype.itemsize
        else:
            K, N = w.shape[-2:]
            if K % block[0] or N % block[1]:
                return skip(f"block {block} does not divide ({K}, {N})")
            packed, stats = _pack_stacked(w, mask, block, reorder=reorder,
                                          n_bins=gemm_bins)
        if stats["flops_saved"] <= min_saving:
            return skip(f"no effective saving (L={stats['L']} of "
                        f"Kb={stats['Kb']} column blocks survive)")
        out["packed"] = packed
        if not keep_dense:
            del out["w"]
        report.append({"path": wpath, "packed": True, "kind": kind, **stats})
        return out

    exec_params = walk(params, masks, "")
    if artifact_key is not None:
        # publish for the next (replica) start; best-effort — an
        # unwritable store must never fail the compile itself
        try:
            ART.save_artifact(artifact_dir, artifact_key, exec_params,
                              report)
        except OSError as e:
            import logging
            logging.getLogger("repro.serve.artifacts").warning(
                "could not publish artifact to %s: %s", artifact_dir, e)
    return exec_params, report


def compiled_summary(report) -> str:
    """One-line-per-layer compile log, including the load-balance lever
    (pre-reorder L -> post-reorder effective L and the gain) and, for conv
    layers, the im2col patch bytes per output position the implicit-GEMM
    path avoids allocating (total avoided = B*Ho*Wo of these)."""
    lines = []
    for r in report:
        if r["packed"]:
            line = (
                f"  pack {r['path']:<28s} [{r.get('kind', 'linear')}] "
                f"block={r['block']} "
                f"density={r['density']:.2f} "
                f"L={r['L']}->{r['L_reordered']}/{r['Kb']} "
                f"(reorder_gain={r['reorder_gain']:.2f}x) "
                f"flops_saved={r['flops_saved']:.2f}")
            if "patch_b_per_pos" in r:
                line += f" implicit_avoids={r['patch_b_per_pos']}B/pos"
            lines.append(line)
        else:
            lines.append(f"  skip {r['path']:<28s} ({r['reason']})")
    return "\n".join(lines)
