"""Model "codegen" (paper §4.3): pruning masks + per-layer scheme mapping
-> packed execution params.

``compile_model`` is the compiler step the paper describes between pruning
and deployment: given trained params, the {0,1} mask tree, and the
per-layer scheme mapping produced by ``core.mapper_rule``/``mapper_search``,
it packs every block-pruned projection into the uniform BCS/CSC layout and
installs it as ``params[...]["packed"]`` so ``models.layers.linear`` (and
therefore attention qkv/out, FFN gate/up/down) dispatches through the
Pallas block-sparse kernel — PatDNN-style sparsity baked into the executed
code, adapted to TPU tiles.

Layer stacks are scanned over a stacked layer axis, so per-layer packed
layouts are padded to a common max column degree L and stacked — one
pallas_call per projection *kind*, not per layer.  Packing itself is
vectorized + content-cached (see ``kernels.ops.pack``); a second compile of
the same weights is free.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import reweighted as RW
from repro.kernels import ops

# schemes whose masks the BCS executor can exploit (whole blocks die)
BLOCK_SCHEMES = ("block", "block_row", "block_col")


def _pack_stacked(w, mask, block):
    """Pack (..., K, N) weights slice-by-slice, pad every slice's column
    degree to the stack max, and restack -> scan-compatible packed arrays.

    Returns ({"values", "k_idx"}, stats)."""
    w = np.asarray(w)
    mask = np.broadcast_to(np.asarray(mask), w.shape)
    lead = w.shape[:-2]
    K, N = w.shape[-2:]
    bk, bn = block
    Kb = K // bk
    wf = w.reshape((-1, K, N))
    mf = mask.reshape((-1, K, N))
    packs = [ops.pack(wf[i], mf[i], block) for i in range(wf.shape[0])]
    Lmax = max(p["values"].shape[1] for p in packs)
    vals, kidx = [], []
    for p in packs:
        v = np.asarray(p["values"])
        k = np.asarray(p["k_idx"])
        pad = Lmax - v.shape[1]
        if pad:
            v = np.concatenate(
                [v, np.zeros((v.shape[0], pad) + v.shape[2:], v.dtype)], 1)
            k = np.concatenate(
                [k, np.zeros((k.shape[0], pad), k.dtype)], 1)
        vals.append(v)
        kidx.append(k)
    values = np.stack(vals).reshape(lead + vals[0].shape)
    k_idx = np.stack(kidx).reshape(lead + kidx[0].shape)
    stats = {
        "block": tuple(block), "shape": (K, N), "L": Lmax, "Kb": Kb,
        "density": float(np.mean([p["density"] for p in packs])),
        "flops_saved": max(0.0, 1.0 - Lmax / Kb),
        "layers": int(np.prod(lead)) if lead else 1,
    }
    return {"values": jnp.asarray(values), "k_idx": jnp.asarray(k_idx)}, stats


def compile_model(params, masks=None, mapping=(), *, block_override=None,
                  keep_dense=True, min_saving=0.0,
                  exclude=("router", "moe/", "embed", "head")):
    """Pack every block-pruned linear layer of ``params`` for sparse
    execution.  Returns (exec_params, report).

    params   : model param tree (nested dicts; linear nodes hold "w").
    masks    : {0,1} mask tree matching ``params`` (scalar sentinels on
               unpruned leaves, as built by ``reweighted.masks_for_spec``).
               None derives masks from the zeros already baked into ``w``
               (i.e. params after ``trainer.apply_masks``).
    mapping  : PruneSpec [(path_regex, SchemeChoice)] from the mapper —
               only paths mapped to a block scheme are packed.
    block_override : force one (bk, bn) packing block for every layer
               (otherwise each layer uses its mapped choice.block).
    keep_dense : keep "w" next to "packed" (dense fallback / debugging);
               False drops it to halve serving weight memory.
    min_saving : skip packing when the effective skipped-FLOP fraction
               (1 - L/Kb under the uniform-padded layout) is not above
               this — a padded layout with no skipping would only add
               gather overhead.
    exclude  : path substrings never packed (router/embeddings per §5.2.4;
               MoE expert einsums don't dispatch through layers.linear yet).

    Every packed node's report entry carries the effective density, padded
    column degree L, and skipped-FLOP fraction; skipped nodes carry the
    reason, so the report doubles as the compile log.
    """
    report = []

    def walk(p, m, path):
        if not isinstance(p, dict):
            return p
        out = {k: walk(v, m.get(k) if isinstance(m, dict) else None,
                       f"{path}/{k}" if path else k)
               for k, v in p.items()}
        w = p.get("w")
        if w is None or isinstance(w, dict) or getattr(w, "ndim", 0) < 2:
            return out
        wpath = f"{path}/w" if path else "w"

        def skip(reason):
            report.append({"path": wpath, "packed": False, "reason": reason})
            return out

        if any(e in wpath for e in exclude):
            return skip("excluded")
        choice = RW.match(list(mapping), wpath)
        if choice is None or choice.scheme not in BLOCK_SCHEMES:
            return skip("no block scheme mapped")
        mask = m.get("w") if isinstance(m, dict) else None
        if masks is None:
            mask = np.asarray(w) != 0
        elif mask is None or getattr(mask, "ndim", 0) == 0:
            return skip("no mask (layer not pruned)")
        block = tuple(block_override or choice.block)
        K, N = w.shape[-2:]
        if K % block[0] or N % block[1]:
            return skip(f"block {block} does not divide ({K}, {N})")
        packed, stats = _pack_stacked(w, mask, block)
        if stats["flops_saved"] <= min_saving:
            return skip(f"no effective saving (L={stats['L']} of "
                        f"Kb={stats['Kb']} column blocks survive)")
        out["packed"] = packed
        if not keep_dense:
            del out["w"]
        report.append({"path": wpath, "packed": True, **stats})
        return out

    return walk(params, masks, ""), report


def compiled_summary(report) -> str:
    """One-line-per-layer compile log."""
    lines = []
    for r in report:
        if r["packed"]:
            lines.append(
                f"  pack {r['path']:<28s} block={r['block']} "
                f"density={r['density']:.2f} L={r['L']}/{r['Kb']} "
                f"flops_saved={r['flops_saved']:.2f}")
        else:
            lines.append(f"  skip {r['path']:<28s} ({r['reason']})")
    return "\n".join(lines)
