"""Model "codegen" (paper §4.3): pruning masks + per-layer scheme mapping
-> packed execution params.

``compile_model`` is the compiler step the paper describes between pruning
and deployment: given trained params, the {0,1} mask tree, and the
per-layer scheme mapping produced by ``core.mapper_rule``/``mapper_search``,
it packs every block-pruned projection into a ``core.packed.PackedLayout``
— the single interchange format shared by every sparse consumer — and
installs it as ``params[...]["packed"]`` so ``models.layers.linear``
(attention qkv/out, FFN gate/up/down) and the batched MoE expert path in
``models.moe`` dispatch through the Pallas block-sparse kernel —
PatDNN-style sparsity baked into the executed code, adapted to TPU tiles.

Row reordering for load balance (Fig 4) happens here by default
(``reorder=True``): block columns are degree-sorted and binned before
padding, so the executed column degree drops from the max toward the mean
(the report carries ``L`` -> ``L_reordered`` and the gain per layer).

Layer stacks are scanned over a stacked layer axis (MoE expert weights add
an expert axis), so per-layer layouts are padded to common per-bin column
degrees and stacked — one pallas_call per projection *kind* and bin, not
per layer.  Packing itself is vectorized + content-cached (see
``kernels.ops.pack``); a second compile of the same weights is free.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import reweighted as RW
from repro.core.packed import PackedLayout
from repro.kernels import ops

# schemes whose masks the BCS executor can exploit (whole blocks die)
BLOCK_SCHEMES = ("block", "block_row", "block_col")


def _stack_pad_L(arrays, Lb):
    """Stack per-slice bin arrays after zero-padding axis 1 (the column
    degree) to ``Lb`` — padding slots keep k_idx 0 / zero values."""
    out = []
    for a in arrays:
        a = np.asarray(a)
        pad = Lb - a.shape[1]
        if pad:
            a = np.concatenate(
                [a, np.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)], 1)
        out.append(a)
    return np.stack(out)


def _pack_stacked(w, mask, block, *, reorder=True, n_bins=4):
    """Pack (..., K, N) weights slice-by-slice, pad every slice's per-bin
    column degree to the stack max, and restack -> a scan/vmap-compatible
    ``PackedLayout`` whose leaves carry the leading stack dims (layers,
    experts, or both).

    Returns (PackedLayout, stats)."""
    w = np.asarray(w)
    mask = np.broadcast_to(np.asarray(mask), w.shape)
    lead = w.shape[:-2]
    K, N = w.shape[-2:]
    bk, bn = block
    Kb = K // bk
    wf = w.reshape((-1, K, N))
    mf = mask.reshape((-1, K, N))
    layouts = [ops.pack(wf[i], mf[i], block, reorder=reorder, n_bins=n_bins)
               for i in range(wf.shape[0])]
    nb = layouts[0].n_bins                    # identical across slices
    values, k_idx = [], []
    for b in range(nb):
        Lb = max(l.bin_degrees[b] for l in layouts)
        values.append(jnp.asarray(_stack_pad_L(
            [l.values[b] for l in layouts], Lb).reshape(
                lead + (-1, Lb, bk, bn))))
        k_idx.append(jnp.asarray(_stack_pad_L(
            [l.k_idx[b] for l in layouts], Lb).reshape(lead + (-1, Lb))))

    def restack(get):
        a = np.stack([np.asarray(get(l)) for l in layouts])
        return jnp.asarray(a.reshape(lead + a.shape[1:]))

    nnz = restack(lambda l: l.nnz)
    perm = restack(lambda l: l.perm) if reorder else None
    inv_perm = restack(lambda l: l.inv_perm) if reorder else None
    stacked = PackedLayout(values=tuple(values), k_idx=tuple(k_idx),
                           nnz=nnz, perm=perm, inv_perm=inv_perm,
                           block=tuple(block), shape=(K, N))
    # L: the padded max column degree (what every column pays without
    # reordering); L_reordered: mean executed degree under the binned
    # stacked layout.  Equal when reorder is off.
    L_pre = max(1, int(np.asarray(nnz).max()))
    L_eff = stacked.L_effective
    stats = {
        "block": tuple(block), "shape": (K, N), "L": L_pre, "Kb": Kb,
        "L_reordered": round(L_eff, 2),
        "reorder_gain": round(L_pre / max(L_eff, 1e-9), 2),
        "density": stacked.density,
        "flops_saved": stacked.flops_saved,
        "layers": int(np.prod(lead)) if lead else 1,
    }
    return stacked, stats


def compile_model(params, masks=None, mapping=(), *, block_override=None,
                  keep_dense=True, min_saving=0.0, reorder=True, n_bins=4,
                  exclude=("router", "embed", "head")):
    """Pack every block-pruned linear layer of ``params`` for sparse
    execution.  Returns (exec_params, report).

    params   : model param tree (nested dicts; linear nodes hold "w").
    masks    : {0,1} mask tree matching ``params`` (scalar sentinels on
               unpruned leaves, as built by ``reweighted.masks_for_spec``).
               None derives masks from the zeros already baked into ``w``
               (i.e. params after ``trainer.apply_masks``).
    mapping  : PruneSpec [(path_regex, SchemeChoice)] from the mapper —
               only paths mapped to a block scheme are packed.
    block_override : force one (bk, bn) packing block for every layer
               (otherwise each layer uses its mapped choice.block).
    keep_dense : keep "w" next to "packed" (dense fallback / debugging);
               False drops it to halve serving weight memory.
    min_saving : skip packing when the effective skipped-FLOP fraction
               (1 - executed/(Kb*Nb) under the padded layout) is not above
               this — a padded layout with no skipping would only add
               gather overhead.
    reorder  : degree-sort + bin block columns before padding (paper Fig 4
               row reordering) so L drops toward the mean degree; outputs
               stay bit-identical (see ``core.bcs.pack_csc_reordered``).
    n_bins   : number of degree bins when reordering.
    exclude  : path substrings never packed (router/embeddings per §5.2.4).
               MoE expert projections (gate/up/down) ARE packed — they
               dispatch through ``kernels.ops.sparse_expert_linear``.

    Every packed node's report entry carries the effective density, the
    pre-reorder padded column degree L, the post-reorder ``L_reordered``
    with its gain, and the skipped-FLOP fraction; skipped nodes carry the
    reason, so the report doubles as the compile log.
    """
    report = []

    def walk(p, m, path):
        if not isinstance(p, dict):
            return p
        out = {k: walk(v, m.get(k) if isinstance(m, dict) else None,
                       f"{path}/{k}" if path else k)
               for k, v in p.items()}
        w = p.get("w")
        if w is None or isinstance(w, dict) or getattr(w, "ndim", 0) < 2:
            return out
        wpath = f"{path}/w" if path else "w"

        def skip(reason):
            report.append({"path": wpath, "packed": False, "reason": reason})
            return out

        if any(e in wpath for e in exclude):
            return skip("excluded")
        choice = RW.match(list(mapping), wpath)
        if choice is None or choice.scheme not in BLOCK_SCHEMES:
            return skip("no block scheme mapped")
        mask = m.get("w") if isinstance(m, dict) else None
        if masks is None:
            mask = np.asarray(w) != 0
        elif mask is None or getattr(mask, "ndim", 0) == 0:
            return skip("no mask (layer not pruned)")
        block = tuple(block_override or choice.block)
        K, N = w.shape[-2:]
        if K % block[0] or N % block[1]:
            return skip(f"block {block} does not divide ({K}, {N})")
        packed, stats = _pack_stacked(w, mask, block, reorder=reorder,
                                      n_bins=n_bins)
        if stats["flops_saved"] <= min_saving:
            return skip(f"no effective saving (L={stats['L']} of "
                        f"Kb={stats['Kb']} column blocks survive)")
        out["packed"] = packed
        if not keep_dense:
            del out["w"]
        report.append({"path": wpath, "packed": True, **stats})
        return out

    return walk(params, masks, ""), report


def compiled_summary(report) -> str:
    """One-line-per-layer compile log, including the load-balance lever:
    pre-reorder L -> post-reorder effective L and the gain."""
    lines = []
    for r in report:
        if r["packed"]:
            lines.append(
                f"  pack {r['path']:<28s} block={r['block']} "
                f"density={r['density']:.2f} "
                f"L={r['L']}->{r['L_reordered']}/{r['Kb']} "
                f"(reorder_gain={r['reorder_gain']:.2f}x) "
                f"flops_saved={r['flops_saved']:.2f}")
        else:
            lines.append(f"  skip {r['path']:<28s} ({r['reason']})")
    return "\n".join(lines)
