"""Model "codegen" (paper §4.3): pruning masks + per-layer scheme mapping
-> packed execution params.

``compile_model`` is the compiler step the paper describes between pruning
and deployment: given trained params, the {0,1} mask tree, and the
per-layer scheme mapping produced by ``core.mapper_rule``/``mapper_search``,
it packs every block-pruned layer into a ``core.packed.PackedLayout``
— the single interchange format shared by every sparse consumer — and
installs it as ``params[...]["packed"]`` so ``models.layers.linear``
(attention qkv/out, FFN gate/up/down, SSM in/out projections), the batched
MoE expert path in ``models.moe``, and the conv path in
``models.convnet``/``kernels.ops.sparse_conv2d`` dispatch through the
Pallas block-sparse kernel — PatDNN-style sparsity baked into the executed
code, adapted to TPU tiles.

Layer kinds are detected structurally (``_layer_kind``): block-punched
4-D (P, Q, Kh, Kw) conv weights are im2col-lowered before packing
(``core.bcs.conv_lower``), pattern/connectivity 4-D conv masks are
tap-lowered into a ``core.packed.TapLayout`` (``core.bcs.pattern_lower``)
for the Pallas tap-gather kernel — a pattern pick no longer falls back to
masked-dense — depthwise convs are skipped with a logged reason (§5.2.4),
and everything else packs as a (possibly stacked) GEMM.

Row reordering for load balance (Fig 4) happens here by default
(``reorder=True``): block columns are degree-sorted and binned before
padding, so the executed column degree drops from the max toward the mean
(the report carries ``L`` -> ``L_reordered`` and the gain per layer).

Layer stacks are scanned over a stacked layer axis (MoE expert weights add
an expert axis), so per-layer layouts are padded to common per-bin column
degrees and stacked — one pallas_call per projection *kind* and bin, not
per layer.  Packing itself is vectorized + content-cached (see
``kernels.ops.pack``); a second compile of the same weights is free.

The compile knobs live in one frozen ``CompileSpec`` — the primary
``compile_model(params, masks, mapping, spec=...)`` signature — and the
spec (not ad-hoc kwarg tuples) feeds both the pack-cache keys and the
artifact ``model_digest``, so equivalent invocations hit the same cache
entries however they were spelled.  The old keyword pile
(``keep_dense=``, ``reorder=``, ...) still works as a deprecation shim
that builds a spec.  ``spec.value_dtype="int8"`` turns on the quantized
value path (``core.quant``): packed values are stored int8 with fp32
scale leaves and the Pallas kernels dequantize in-kernel; a per-layer
``SchemeChoice.value_dtype`` (the mapper's precision pick) overrides the
spec default.

The per-layer outcome is returned as a typed ``CompileReport`` (one
``LayerReport`` per visited layer: kind, scheme, L -> L_reordered,
executed fraction, value dtype, or the skip reason), serialized verbatim
into the artifact manifest; ``compiled_summary`` renders it.  Reports
keep a dict-style item protocol, so existing ``row["path"]`` consumers
keep working.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import quant as QUANT
from repro.core import reweighted as RW
from repro.core.packed import PackedLayout
from repro.kernels import ops

# schemes the sparse executors can exploit: FC block schemes pack the
# weight as-is; block_punched (the paper's CONV scheme) packs the
# im2col-lowered weight into whole dead BCS blocks; pattern (incl.
# connectivity pruning) carries no block structure and tap-lowers into a
# TapLayout for the tap-gather kernel — see _layer_kind below.
BLOCK_SCHEMES = ("block", "block_row", "block_col")
CONV_SCHEMES = ("block_punched",)
PATTERN_SCHEMES = ("pattern",)
PACKABLE_SCHEMES = BLOCK_SCHEMES + CONV_SCHEMES + PATTERN_SCHEMES

# value dtypes the packed executors can serve (None = keep float values)
VALUE_DTYPES = (None, "int8")


@dataclasses.dataclass(frozen=True)
class CompileSpec:
    """All ``compile_model`` knobs in one frozen, hashable value.

    keep_dense : keep "w" next to "packed" (dense fallback / debugging);
        False drops it to halve serving weight memory.
    reorder : degree-sort + bin block columns before padding (paper Fig 4
        row reordering) so L drops toward the mean degree; outputs stay
        bit-identical (``core.bcs.pack_csc_reordered``).
    n_bins : number of degree bins when reordering.  None uses each
        producer's own default: 4 for block layouts, 8 for tap layouts.
    block_override : force one (bk, bn) packing block for every layer
        (otherwise each layer uses its mapped choice.block).
    min_saving : skip packing when the effective skipped-FLOP fraction is
        not above this.
    implicit : conv x-operand strategy hint for serving dispatch
        (None = auto by patch size, see ``kernels.ops._pick_implicit``).
        Recorded with the report; does not change the packed layouts.
    value_dtype : default serving precision for packed values — None keeps
        float, "int8" quantizes symmetrically with fp32 scale leaves
        (``core.quant``); a per-layer ``SchemeChoice.value_dtype``
        (the mapper's precision pick) overrides this default.
    scale_granularity : scale group for quantized BCS layouts — "block"
        (one fp32 per stored block) or "out" (one per block column).
        Tap layouts always quantize per-filter ("out"): their group=1
        slots hold single values, so a per-slot scale would cost 4 bytes
        per stored value.
    exclude : path substrings never packed (router/embeddings per §5.2.4).
    tp : tensor-parallel degree over the mesh "model" axis.  tp > 1
        column-shards every packed layout (degree-balanced LPT assignment,
        ``core.bcs.shard_columns``) so the shard-parallel kernel drivers
        split block columns across devices.  MoE expert layers under a
        ``moe/`` path are exempt — their expert stack axis already shards
        along "model" for free (``sparse_expert_linear`` asserts it).
        A layer whose column-block count tp does not divide falls back to
        the unsharded layout (reported per layer via ``shards``).

    ``digest_fields()`` is the spec's contribution to the pack-cache key
    and the artifact ``model_digest``: exactly the fields that change the
    produced layouts (``keep_dense`` and ``implicit`` are excluded — they
    only affect serving-time dispatch), so equivalent invocations digest
    identically however the spec was built.
    """
    keep_dense: bool = True
    reorder: bool = True
    n_bins: int | None = None
    block_override: tuple | None = None
    min_saving: float = 0.0
    implicit: bool | None = None
    value_dtype: str | None = None
    scale_granularity: str = "block"
    exclude: tuple = ("router", "embed", "head")
    tp: int = 1

    def __post_init__(self):
        """Validate + normalize (tuples for hashability, checked enums)."""
        if self.value_dtype not in VALUE_DTYPES:
            raise ValueError(f"value_dtype {self.value_dtype!r} not in "
                             f"{VALUE_DTYPES}")
        if self.scale_granularity not in QUANT.GRANULARITIES:
            raise ValueError(
                f"scale_granularity {self.scale_granularity!r} not in "
                f"{QUANT.GRANULARITIES}")
        if self.block_override is not None:
            bo = tuple(int(b) for b in self.block_override)
            if len(bo) != 2:
                raise ValueError(f"block_override must be (bk, bn), got "
                                 f"{self.block_override!r}")
            object.__setattr__(self, "block_override", bo)
        object.__setattr__(self, "exclude", tuple(self.exclude))
        if self.n_bins is not None:
            object.__setattr__(self, "n_bins", int(self.n_bins))
        if int(self.tp) < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        object.__setattr__(self, "tp", int(self.tp))

    def digest_fields(self) -> tuple:
        """The layout-determining fields, in a stable order — what the
        artifact ``model_digest`` hashes for the compile-knob part."""
        return (self.block_override, float(self.min_saving),
                bool(self.reorder), self.n_bins, tuple(self.exclude),
                self.value_dtype, str(self.scale_granularity),
                int(self.tp))

    def to_json(self) -> dict:
        """Plain-JSON form (manifest serialization)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "CompileSpec":
        """Rebuild from ``to_json`` output (lists back to tuples)."""
        d = dict(d)
        if d.get("block_override") is not None:
            d["block_override"] = tuple(d["block_override"])
        if d.get("exclude") is not None:
            d["exclude"] = tuple(d["exclude"])
        return cls(**d)


# LayerReport fields always present in the item protocol even when falsy
_ALWAYS_KEYS = ("path", "packed")


@dataclasses.dataclass(frozen=True)
class LayerReport:
    """One layer's line of the compile log, typed.

    ``packed`` rows carry the layout geometry and the load-balance lever
    (pre-reorder padded degree ``L`` -> post-reorder ``L_reordered`` of
    ``Kb`` column blocks), the executed fraction (``1 - flops_saved``),
    the mapped ``scheme`` and the served ``value_dtype`` (None = float).
    Skipped rows carry the ``reason``; rows whose layout later failed
    validation and was retired by ``degrade_invalid_layers`` carry
    ``degraded=True`` plus the failure reason.  A dict-style item protocol
    (``row["path"]``, ``row.get(...)``, ``"kind" in row`` — None fields
    read as absent) keeps the historical dict-row consumers working.
    """
    path: str
    packed: bool
    kind: str | None = None
    scheme: str | None = None
    reason: str | None = None
    block: tuple | None = None
    shape: tuple | None = None
    L: int | None = None
    Kb: int | None = None
    L_reordered: float | None = None
    reorder_gain: float | None = None
    density: float | None = None
    flops_saved: float | None = None
    layers: int | None = None
    value_dtype: str | None = None
    patch_b_per_pos: int | None = None
    shards: int | None = None
    degraded: bool | None = None

    @property
    def executed_frac(self) -> float | None:
        """Fraction of dense FLOPs the padded layout actually executes."""
        return None if self.flops_saved is None else 1.0 - self.flops_saved

    def __getitem__(self, key):
        """Dict-style field access; None-valued fields raise KeyError."""
        v = getattr(self, key, None) if not key.startswith("_") else None
        if v is None and key not in _ALWAYS_KEYS:
            raise KeyError(key)
        return v

    def get(self, key, default=None):
        """Dict-style ``get`` over the non-None fields."""
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key) -> bool:
        """Dict-style membership: a field is "present" when non-None."""
        return self.get(key) is not None or key in _ALWAYS_KEYS

    def to_json(self) -> dict:
        """Plain-JSON row: only the present (non-None) fields."""
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None or k in _ALWAYS_KEYS}

    @classmethod
    def from_json(cls, d: dict) -> "LayerReport":
        """Rebuild from ``to_json`` output (lists back to tuples)."""
        d = {k: v for k, v in d.items()
             if k in {f.name for f in dataclasses.fields(cls)}}
        for k in ("block", "shape"):
            if d.get(k) is not None:
                d[k] = tuple(d[k])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CompileReport:
    """The typed compile log ``compile_model`` returns (and the artifact
    manifest stores verbatim): one ``LayerReport`` per visited layer plus
    the ``CompileSpec`` that produced it.  Iterates/indexes like the
    historical list of rows."""
    rows: tuple = ()
    spec: CompileSpec | None = None

    def __iter__(self):
        """Iterate the per-layer rows."""
        return iter(self.rows)

    def __len__(self) -> int:
        """Number of per-layer rows."""
        return len(self.rows)

    def __getitem__(self, i):
        """Index the per-layer rows (int or slice)."""
        return self.rows[i]

    @property
    def packed(self) -> tuple:
        """The rows that produced a layout."""
        return tuple(r for r in self.rows if r.packed)

    @property
    def skipped(self) -> tuple:
        """The rows skipped with a reason."""
        return tuple(r for r in self.rows if not r.packed)

    def to_json(self) -> dict:
        """Manifest form: {"spec": ..., "layers": [row, ...]}."""
        return {"spec": self.spec.to_json() if self.spec else None,
                "layers": [r.to_json() for r in self.rows]}

    @classmethod
    def from_json(cls, d) -> "CompileReport":
        """Rebuild from ``to_json`` output — also accepts the historical
        bare list-of-row-dicts manifests."""
        if isinstance(d, dict):
            spec = (CompileSpec.from_json(d["spec"])
                    if d.get("spec") else None)
            rows = d.get("layers", ())
        else:
            spec, rows = None, d
        return cls(rows=tuple(LayerReport.from_json(r) for r in rows),
                   spec=spec)


def _layer_kind(w, scheme: str) -> str:
    """Structural layer-kind detection — what decides the layout producer,
    instead of path-name heuristics:

      conv         : 4-D (P, Q, Kh, Kw) weight mapped to a CONV block
                     scheme -> im2col BCS producer
      pattern_conv : 4-D conv weight mapped to the pattern scheme ->
                     tap-gather producer (per-kernel pattern masks carry no
                     block structure, so the skippable unit is a tap)
      depthwise    : conv with Q == 1 (never packed, §5.2.4)
      linear       : trailing (K, N) GEMM weight, any leading stack dims
                     (scanned layers, MoE experts, or both)

    The mapped scheme disambiguates rank-4 weights: a stacked MoE expert
    weight (L, E, K, N) is also 4-D, but the mapper only ever assigns
    ``block_punched``/``pattern`` to real conv weights (their groups are
    kernel positions), so scheme + rank identifies the producer."""
    if scheme in CONV_SCHEMES + PATTERN_SCHEMES:
        if getattr(w, "ndim", 0) != 4:
            return "bad_conv"
        if w.shape[1] == 1:
            return "depthwise"
        return "pattern_conv" if scheme in PATTERN_SCHEMES else "conv"
    return "linear"


def _stack_pad_L(arrays, Lb, axis=1):
    """Stack per-slice bin arrays after zero-padding ``axis`` (the column
    degree — 1 unsharded, 2 behind the shard axis) to ``Lb`` — padding
    slots keep k_idx 0 / zero values."""
    out = []
    for a in arrays:
        a = np.asarray(a)
        pad = Lb - a.shape[axis]
        if pad:
            shp = list(a.shape)
            shp[axis] = pad
            a = np.concatenate([a, np.zeros(shp, a.dtype)], axis)
        out.append(a)
    return np.stack(out)


def _pack_stacked(w, mask, block, *, reorder=True, n_bins=4,
                  value_dtype=None, scale_granularity="block", n_shards=0):
    """Pack (..., K, N) weights slice-by-slice, pad every slice's per-bin
    column degree to the stack max, and restack -> a scan/vmap-compatible
    ``PackedLayout`` whose leaves carry the leading stack dims (layers,
    experts, or both).  ``value_dtype="int8"`` quantizes the STACKED
    layout (one ``core.quant`` pass over the restacked leaves — the
    per-slice float packs stay cached as-is).  ``n_shards`` > 0 shards
    every slice's block columns tensor-parallel (degree-balanced LPT,
    ``core.bcs.shard_columns``); the shard axis stays the innermost stack
    dim on every per-bin leaf.

    Returns (PackedLayout, stats)."""
    w = np.asarray(w)
    mask = np.broadcast_to(np.asarray(mask), w.shape)
    lead = w.shape[:-2]
    K, N = w.shape[-2:]
    bk, bn = block
    Kb = K // bk
    S = int(n_shards)
    wf = w.reshape((-1, K, N))
    mf = mask.reshape((-1, K, N))
    layouts = [ops.pack(wf[i], mf[i], block, reorder=reorder, n_bins=n_bins,
                        n_shards=S)
               for i in range(wf.shape[0])]
    nb = layouts[0].n_bins                    # identical across slices
    shard = (S,) if S else ()
    deg_axis = 2 if S else 1                  # degree sits behind the shard
    values, k_idx = [], []
    for b in range(nb):
        Lb = max(l.bin_degrees[b] for l in layouts)
        values.append(jnp.asarray(_stack_pad_L(
            [l.values[b] for l in layouts], Lb, deg_axis).reshape(
                lead + shard + (-1, Lb, bk, bn))))
        k_idx.append(jnp.asarray(_stack_pad_L(
            [l.k_idx[b] for l in layouts], Lb, deg_axis).reshape(
                lead + shard + (-1, Lb))))

    def restack(get):
        a = np.stack([np.asarray(get(l)) for l in layouts])
        return jnp.asarray(a.reshape(lead + a.shape[1:]))

    nnz = restack(lambda l: l.nnz)
    has_perm = reorder or S
    perm = restack(lambda l: l.perm) if has_perm else None
    inv_perm = restack(lambda l: l.inv_perm) if has_perm else None
    stacked = PackedLayout(values=tuple(values), k_idx=tuple(k_idx),
                           nnz=nnz, perm=perm, inv_perm=inv_perm,
                           block=tuple(block), shape=(K, N), n_shards=S)
    if value_dtype is not None:
        stacked = QUANT.quantize_layout(
            stacked, value_dtype=value_dtype,
            scale_granularity=scale_granularity)
    # L: the padded max column degree (what every column pays without
    # reordering); L_reordered: mean executed degree under the binned
    # stacked layout.  Equal when reorder is off.
    L_pre = max(1, int(np.asarray(nnz).max()))
    L_eff = stacked.L_effective
    stats = {
        "block": tuple(block), "shape": (K, N), "L": L_pre, "Kb": Kb,
        "L_reordered": round(L_eff, 2),
        "reorder_gain": round(L_pre / max(L_eff, 1e-9), 2),
        "density": stacked.density,
        "flops_saved": stacked.flops_saved,
        "layers": int(np.prod(lead)) if lead else 1,
    }
    return stacked, stats


# the historical compile_model keyword pile, now a deprecation shim that
# builds a CompileSpec (same defaults)
_LEGACY_SPEC_KWARGS = ("block_override", "keep_dense", "min_saving",
                       "reorder", "n_bins", "exclude", "implicit",
                       "value_dtype", "scale_granularity")


def resolve_spec(spec=None, **legacy) -> CompileSpec:
    """Resolve the ``spec``-or-legacy-kwargs compile surface to one
    ``CompileSpec``: pass ``spec`` through, build one from the historical
    keywords (DeprecationWarning), reject mixing the two."""
    legacy = {k: v for k, v in legacy.items() if v is not None}
    bad = set(legacy) - set(_LEGACY_SPEC_KWARGS)
    if bad:
        raise TypeError(f"unknown compile_model argument(s): {sorted(bad)}")
    if spec is not None:
        if legacy:
            raise TypeError(
                f"pass spec= OR legacy keywords, not both (got spec and "
                f"{sorted(legacy)})")
        if not isinstance(spec, CompileSpec):
            raise TypeError(f"spec must be a CompileSpec, got "
                            f"{type(spec).__name__}")
        return spec
    if legacy:
        warnings.warn(
            "compile_model(keep_dense=..., reorder=..., ...) keywords are "
            "deprecated; pass spec=CompileSpec(...) instead",
            DeprecationWarning, stacklevel=3)
    return CompileSpec(**legacy)


def compile_model(params, masks=None, mapping=(), spec=None, *,
                  artifact_dir=None, **legacy):
    """Pack every block-pruned linear/conv layer of ``params`` for sparse
    execution.  Returns (exec_params, CompileReport).

    params   : model param tree (nested dicts; linear nodes hold "w").
    masks    : {0,1} mask tree matching ``params`` (scalar sentinels on
               unpruned leaves, as built by ``reweighted.masks_for_spec``).
               None derives masks from the zeros already baked into ``w``
               (i.e. params after ``trainer.apply_masks``).
    mapping  : PruneSpec [(path_regex, SchemeChoice)] from the mapper —
               only paths mapped to a packable scheme are packed (FC block
               schemes pack the weight as-is; ``block_punched`` conv
               layers pack the im2col-lowered weight; ``pattern`` conv
               layers tap-lower into a TapLayout for the tap-gather
               kernel).  A choice's ``value_dtype`` (the mapper's
               precision pick) overrides ``spec.value_dtype`` per layer.
    spec     : ``CompileSpec`` — the primary compile surface; see its
               docstring for every knob.  The historical keywords
               (``keep_dense=``, ``reorder=``, ``n_bins=``,
               ``block_override=``, ``min_saving=``, ``exclude=``, plus
               the new ``implicit=``/``value_dtype=``/
               ``scale_granularity=``) still work as a deprecation shim
               that builds an equivalent spec; mixing both is an error.
    artifact_dir : AOT artifact store (``serve.artifacts``).  When set,
               the model digest (weights + masks + mapping + spec digest
               fields) is looked up first: digest match -> checksum verify
               -> layout validation -> warm start with the stored layouts
               grafted on (no packing at all).  Digest mismatch, checksum
               failure, version skew, or invariant violation logs its
               structured reason and falls back to THIS fresh pack, whose
               result is then published crash-safely (tmp + atomic
               rename) for the next start.

    Every packed ``LayerReport`` carries the effective density, the
    pre-reorder padded column degree L, the post-reorder ``L_reordered``
    with its gain, the skipped-FLOP fraction, and the served value dtype;
    skipped rows carry the reason, so the report doubles as the compile
    log.
    """
    spec = resolve_spec(spec, **legacy)
    artifact_key = None
    if artifact_dir is not None:
        from repro.serve import artifacts as ART
        artifact_key = ART.model_digest(params, masks, mapping, spec=spec)
        warm = ART.load_grafted(artifact_dir, artifact_key, params,
                                keep_dense=spec.keep_dense)
        if warm is not None:
            return warm

    rows = []
    # per-producer bin defaults (None = use each producer's own): block
    # layouts 4, tap layouts 8 — see kernels.ops.pack_taps
    gemm_bins = 4 if spec.n_bins is None else spec.n_bins
    tap_bins = 8 if spec.n_bins is None else spec.n_bins
    reorder = spec.reorder

    def walk(p, m, path):
        if not isinstance(p, dict):
            return p
        out = {k: walk(v, m.get(k) if isinstance(m, dict) else None,
                       f"{path}/{k}" if path else k)
               for k, v in p.items()}
        w = p.get("w")
        if w is None or isinstance(w, dict) or getattr(w, "ndim", 0) < 2:
            return out
        wpath = f"{path}/w" if path else "w"

        def skip(reason):
            rows.append(LayerReport(path=wpath, packed=False, reason=reason))
            return out

        if any(e in wpath for e in spec.exclude):
            return skip("excluded")
        choice = RW.match(list(mapping), wpath)
        if choice is None or choice.scheme not in PACKABLE_SCHEMES:
            return skip("no block scheme mapped")
        kind = _layer_kind(w, choice.scheme)
        if kind == "depthwise":
            return skip("depthwise conv never packed (§5.2.4)")
        if kind == "bad_conv":
            return skip(f"{choice.scheme} needs a (P, Q, Kh, Kw) conv "
                        f"weight, got shape {tuple(w.shape)}")
        mask = m.get("w") if isinstance(m, dict) else None
        if masks is None:
            mask = np.asarray(w) != 0
        elif mask is None or getattr(mask, "ndim", 0) == 0:
            return skip("no mask (layer not pruned)")
        block = tuple(spec.block_override or choice.block)
        # per-layer precision: the mapper's pick wins over the spec default
        vdt = getattr(choice, "value_dtype", None) or spec.value_dtype
        if vdt not in VALUE_DTYPES:
            return skip(f"unsupported value_dtype {vdt!r}")
        # tensor-parallel column sharding: MoE expert stacks are exempt
        # (their leading expert axis shards along "model" for free —
        # sparse_expert_linear asserts column sharding never reaches it);
        # a layer whose column count tp does not divide falls back to the
        # unsharded layout, recorded via the report's ``shards`` field.
        shards = 0 if "moe" in wpath.split("/") else (
            spec.tp if spec.tp > 1 else 0)
        if kind == "pattern_conv":
            # tap producer: pattern/connectivity masks carry no block
            # structure (every kernel keeps its own tap set), so the layer
            # lowers to per-filter tap lists over the im2col band and
            # executes through the tap-gather kernel — the scheme the
            # mapper picked for accuracy now runs sparsely instead of
            # silently falling back to masked-dense.  Quantized taps always
            # use per-filter ("out") scales — group=1 slots hold single
            # values, so per-slot scales would cost 4 bytes per value.
            if shards and w.shape[0] % shards:
                shards = 0                      # tp does not divide filters
            tap = ops.pack_taps(w, mask, reorder=reorder, n_bins=tap_bins,
                                value_dtype=vdt, scale_granularity="out",
                                n_shards=shards)
            P, Q, Kh, Kw = w.shape
            stats = {
                "block": (1, tap.group), "shape": tap.shape,
                "L": tap.L_max, "Kb": tap.shape[0],
                "L_reordered": round(tap.L_effective, 2),
                "reorder_gain": round(
                    tap.L_max / max(tap.L_effective, 1e-9), 2),
                "density": tap.density,
                "flops_saved": tap.flops_saved,
                "layers": 1,
                # implicit-GEMM accounting: patch bytes the materialized
                # path would allocate PER OUTPUT POSITION (total = B*Ho*Wo
                # of these), which the implicit tap kernel never touches
                "patch_b_per_pos": Kh * Kw * Q * w.dtype.itemsize,
            }
            packed = tap
        elif kind == "conv":
            # im2col producer: lower weight AND mask to the GEMM the conv
            # executes as (kernels.ops.sparse_conv2d), then reuse the one
            # packing pipeline.  The kernel-block choice (bp filters, bq
            # channels) becomes GEMM block (bq, bp) — see bcs.conv_lower.
            gemm_block, why = BCS.conv_gemm_block(block, w.shape)
            if gemm_block is None:
                return skip(why)
            P, Q, Kh, Kw = w.shape
            wl = BCS.conv_lower(w)
            ml = BCS.conv_lower(np.broadcast_to(np.asarray(mask), w.shape))
            if shards and (wl.shape[-1] // gemm_block[1]) % shards:
                shards = 0                  # tp does not divide Nb
            packed, stats = _pack_stacked(
                wl, ml, gemm_block, reorder=reorder, n_bins=gemm_bins,
                value_dtype=vdt, scale_granularity=spec.scale_granularity,
                n_shards=shards)
            # attach the static tap-offset table so the implicit-GEMM
            # kernel can gather from the feature map without a patch tensor
            packed = dataclasses.replace(
                packed,
                conv_taps=BCS.conv_tap_table(Kh, Kw, Q, gemm_block[0]))
            stats["patch_b_per_pos"] = Kh * Kw * Q * w.dtype.itemsize
        else:
            K, N = w.shape[-2:]
            if K % block[0] or N % block[1]:
                return skip(f"block {block} does not divide ({K}, {N})")
            if shards and (N // block[1]) % shards:
                shards = 0                  # tp does not divide Nb
            packed, stats = _pack_stacked(
                w, mask, block, reorder=reorder, n_bins=gemm_bins,
                value_dtype=vdt, scale_granularity=spec.scale_granularity,
                n_shards=shards)
        if stats["flops_saved"] <= spec.min_saving:
            return skip(f"no effective saving (L={stats['L']} of "
                        f"Kb={stats['Kb']} column blocks survive)")
        out["packed"] = packed
        if not spec.keep_dense:
            del out["w"]
        rows.append(LayerReport(path=wpath, packed=True, kind=kind,
                                scheme=choice.scheme, value_dtype=vdt,
                                shards=shards or None, **stats))
        return out

    exec_params = walk(params, masks, "")
    report = CompileReport(rows=tuple(rows), spec=spec)
    if artifact_key is not None:
        # publish for the next (replica) start; best-effort — an
        # unwritable store must never fail the compile itself
        try:
            ART.save_artifact(artifact_dir, artifact_key, exec_params,
                              report)
        except OSError as e:
            import logging
            logging.getLogger("repro.serve.artifacts").warning(
                "could not publish artifact to %s: %s", artifact_dir, e)
    return exec_params, report


def compiled_summary(report) -> str:
    """One-line-per-layer compile log, including the load-balance lever
    (pre-reorder L -> post-reorder effective L and the gain), the served
    value dtype for quantized layers, and, for conv layers, the im2col
    patch bytes per output position the implicit-GEMM path avoids
    allocating (total avoided = B*Ho*Wo of these).  Accepts a typed
    ``CompileReport`` or the historical list of row dicts."""
    lines = []
    for r in report:
        if r["packed"]:
            line = (
                f"  pack {r['path']:<28s} [{r.get('kind', 'linear')}] "
                f"block={r['block']} "
                f"density={r['density']:.2f} "
                f"L={r['L']}->{r['L_reordered']}/{r['Kb']} "
                f"(reorder_gain={r['reorder_gain']:.2f}x) "
                f"flops_saved={r['flops_saved']:.2f}")
            if r.get("value_dtype"):
                line += f" values={r['value_dtype']}"
            if r.get("shards"):
                line += f" tp={r['shards']}"
            if "patch_b_per_pos" in r:
                line += f" implicit_avoids={r['patch_b_per_pos']}B/pos"
            if r.get("degraded"):
                line += " [DEGRADED -> masked-dense]"
            lines.append(line)
        else:
            lines.append(f"  skip {r['path']:<28s} ({r['reason']})")
    return "\n".join(lines)


def degrade_invalid_layers(exec_params, report=None):
    """Runtime/graft guard: validate every packed layout of an exec-param
    tree and retire any failure to the masked-dense ``DegradedLayer``
    path — that layer alone executes as a dense einsum over its retained
    ``w`` (pruning zeros baked in), every other layer keeps its sparse
    kernel.  Never silent: each degradation logs a structured warning
    and, when a ``CompileReport`` is passed, its matching row is
    re-emitted with ``degraded=True`` and the failure reason.

    Layouts are valid by construction out of ``compile_model`` and fully
    re-validated on artifact graft, so this guard exists for corruption
    that happens AFTER those checks: bit rot in process memory, a buggy
    external layout producer, a chaos-harness injection
    (``repro.testing.faults``).  ``serve.engine.ServingEngine`` runs it at
    construction and counts the result in ``stats["degraded_layers"]``.

    A corrupt layout whose node lost its dense ``w`` (packed with
    ``keep_dense=False``) CANNOT be degraded — the original
    ``LayoutError`` is re-raised, because a repack is the only safe
    answer and a silent wrong result never is.

    Returns ``(exec_params, report, degraded)``: the (skeleton-copied,
    leaf-shared) tree, the updated report (``None``/unknown types pass
    through unchanged), and ``degraded`` as ``[(layer_path,
    LayoutError), ...]``.
    """
    import logging

    from repro.core import validate as V
    from repro.core.packed import DegradedLayer

    log = logging.getLogger("repro.serve.compile")
    degraded = []

    def walk(node, path):
        if not isinstance(node, dict):
            return node
        out = {}
        for k, v in node.items():
            sub = f"{path}/{k}" if path else k
            if k != "packed":
                out[k] = walk(v, sub)
                continue
            if v is None or isinstance(v, (dict, DegradedLayer)):
                out[k] = v
                continue
            try:
                out[k] = V.validate_layout(v, path=sub)
            except V.LayoutError as e:
                if "w" not in node:
                    raise     # no dense fallback weight: repack or die
                out[k] = DegradedLayer(path=path or "packed", code=e.code,
                                       detail=e.detail)
                degraded.append((path, e))
                log.warning(
                    "layer %s: packed layout failed validation — "
                    "degrading to masked-dense execution: %s", path, e)
        return out

    tree = walk(exec_params, "")
    if isinstance(report, CompileReport) and degraded:
        bad = {(f"{p}/w" if p else "w"): e for p, e in degraded}
        rows = tuple(
            dataclasses.replace(
                r, degraded=True,
                reason=f"[{bad[r.path].code}] degraded to masked-dense: "
                       f"{bad[r.path].detail}")
            if r.path in bad else r
            for r in report)
        report = dataclasses.replace(report, rows=rows)
    return tree, report, degraded
