"""Continuous-batching request scheduler: slot admission, eviction, and
per-request state.

Pure Python bookkeeping — no JAX arrays — so every decision is exactly
reproducible: FIFO by submission order with head-of-line arrival gating
(a queued request whose simulated ``arrival`` step is still in the future
blocks the queue, modelling an open-loop workload), admission into the
LOWEST free slot index, eviction the step a stop condition fires.  The
``events`` list is a complete audit trail; two runs over the same
submissions replay identical traces (locked by a regression test).

The scheduler never touches the cache: ``serve.engine.ServingEngine``
pairs each admission/eviction with the matching ``serve.kvcache`` row
write, so scheduler state and slot contents move in lockstep.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass


@dataclass
class Request:
    """One generation request and its accumulated per-request state.

    ``tokens`` grows to at most ``max_new_tokens`` entries (the first is
    the prefill argmax, exactly like ``serve.engine.generate``'s first
    output column); generation also stops early when ``stop_token`` is
    emitted.  ``status`` walks queued -> running -> finished (or
    ``rejected`` when the request can never fit a slot, or ``evicted``
    when the engine aborts it over budget)."""
    rid: int
    prompt: tuple
    max_new_tokens: int
    arrival: int = 0
    stop_token: int | None = None
    status: str = "queued"
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)

    def done(self) -> bool:
        """Stop condition: token budget spent or stop token emitted."""
        if len(self.tokens) >= self.max_new_tokens:
            return True
        return (self.stop_token is not None and bool(self.tokens)
                and self.tokens[-1] == self.stop_token)


class Scheduler:
    """Slot allocator + FIFO queue for the continuous-batching engine."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._slots: list = [None] * n_slots
        self._queue: deque = deque()
        self.events: list = []

    # -- queue side ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue a request (FIFO; callers submit in arrival order)."""
        req.status = "queued"
        self._queue.append(req)
        self.events.append(("submit", req.rid, req.arrival))

    def reject(self, req: Request, reason: str) -> None:
        """Mark a request unservable (e.g. prompt beyond slot capacity)."""
        req.status = "rejected"
        self.events.append(("reject", req.rid, reason))

    # -- slot side ----------------------------------------------------------

    def admit(self, now: int):
        """Admit the queue head into the lowest free slot, if both exist
        and the head has arrived (``arrival <= now``).  Returns
        ``(slot, request)`` or ``None``; loop until ``None`` to refill
        every free slot in one engine step."""
        free = next((i for i, r in enumerate(self._slots) if r is None),
                    None)
        if free is None or not self._queue:
            return None
        if self._queue[0].arrival > now:
            return None
        req = self._queue.popleft()
        req.status, req.slot = "running", free
        self._slots[free] = req
        self.events.append(("admit", req.rid, free, now))
        return free, req

    def release(self, req: Request, status: str = "finished") -> None:
        """Free a running request's slot and record why."""
        self._slots[req.slot] = None
        self.events.append((status, req.rid, req.slot))
        req.status, req.slot = status, None

    # -- queries ------------------------------------------------------------

    def active(self):
        """Occupied slots as ``[(slot, request), ...]`` in slot order."""
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def has_work(self) -> bool:
        """True while anything is queued (even future arrivals) or live."""
        return bool(self._queue) or any(r is not None for r in self._slots)

    def queued(self) -> int:
        """Number of requests still waiting in the queue."""
        return len(self._queue)
