"""Continuous-batching request scheduler: slot admission, eviction, and
per-request state.

Pure Python bookkeeping — no JAX arrays — so every decision is exactly
reproducible: FIFO by submission order with head-of-line arrival gating
(a queued request whose simulated ``arrival`` step is still in the future
blocks the queue, modelling an open-loop workload), admission into the
LOWEST free slot index, eviction the step a stop condition fires.  The
``events`` list is a complete audit trail; two runs over the same
submissions replay identical traces (locked by a regression test).

Fault tolerance (all deterministic, all audited):

* **Queue TTL** — a queued request with ``queue_ttl`` set may wait at most
  that many engine steps past its ``arrival``; ``expire(now)`` sweeps the
  queue in FIFO order and evicts overdue entries with a typed
  ``("expire", rid, REASON_DEADLINE_EXPIRED, now)`` event.  The engine
  runs the sweep at the top of every step, BEFORE admission, so an
  expired request can never race into a slot.
* **Running deadline** — ``deadline_steps`` bounds how many engine steps
  a request may occupy a slot after admission (``admitted_at`` is stamped
  by ``admit``); the ENGINE enforces it (it owns the step counter) via
  ``release(..., reason=REASON_DEADLINE_EXPIRED)``.
* **Bounded retry-with-backoff** — when ``max_queue`` is set and the
  queue is full, a submission with retry budget left is *deferred*
  instead of rejected: it re-submits at ``now + backoff * 2**attempt``
  (exponential, deterministic), at most ``retries`` times, then rejects
  with ``REASON_OVER_BUDGET``.  ``poll_retries(now)`` moves due retries
  back through ``submit`` each engine step.

Typed reasons (``REASON_*``) make the audit trail machine-checkable: a
rejection/expiry/eviction event always says WHY, and replaying the same
workload twice yields byte-identical event lists.

The scheduler never touches the cache: ``serve.engine.ServingEngine``
pairs each admission/eviction with the matching ``serve.kvcache`` row
write, so scheduler state and slot contents move in lockstep.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass

# Typed audit reasons: every reject/expire/evict event carries one of
# these, so the audit trail (and its replay-determinism test) can assert
# WHY a request left the system, not just that it did.
REASON_OVER_BUDGET = "over_budget"
REASON_DEADLINE_EXPIRED = "deadline_expired"
REASON_QUARANTINED = "quarantined"
REASONS = (REASON_OVER_BUDGET, REASON_DEADLINE_EXPIRED, REASON_QUARANTINED)


@dataclass
class Request:
    """One generation request and its accumulated per-request state.

    ``tokens`` grows to at most ``max_new_tokens`` entries (the first is
    the prefill argmax, exactly like ``serve.engine.generate``'s first
    output column); generation also stops early when ``stop_token`` is
    emitted.  ``status`` walks queued -> running -> finished (or
    ``rejected`` when the request can never fit a slot, ``expired`` when
    its queue TTL lapses, ``evicted`` when the engine aborts it over
    budget or past its deadline, ``quarantined`` when its decode logits
    went non-finite, ``deferred`` while waiting out a retry backoff).

    Fault-tolerance knobs (``None``/``0`` = disabled, the default):
    ``deadline_steps`` caps engine steps in a slot after admission,
    ``queue_ttl`` caps engine steps waiting in the queue past ``arrival``,
    ``retries``/``backoff`` bound the queue-full resubmission policy.
    ``admitted_at``/``attempts`` are bookkeeping stamped by the scheduler.
    """
    rid: int
    prompt: tuple
    max_new_tokens: int
    arrival: int = 0
    stop_token: int | None = None
    status: str = "queued"
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)
    deadline_steps: int | None = None
    queue_ttl: int | None = None
    retries: int = 0
    backoff: int = 1
    attempts: int = 0
    admitted_at: int | None = None

    def done(self) -> bool:
        """Stop condition: token budget spent or stop token emitted."""
        if len(self.tokens) >= self.max_new_tokens:
            return True
        return (self.stop_token is not None and bool(self.tokens)
                and self.tokens[-1] == self.stop_token)


class Scheduler:
    """Slot allocator + FIFO queue for the continuous-batching engine.

    ``max_queue`` bounds the waiting line (``None`` = unbounded, the
    seed behaviour): a submission against a full queue defers (bounded
    retry-with-backoff) or rejects with ``REASON_OVER_BUDGET``.
    """

    def __init__(self, n_slots: int, max_queue: int | None = None):
        self.n_slots = n_slots
        self.max_queue = max_queue
        self._slots: list = [None] * n_slots
        self._queue: deque = deque()
        self._retries: list = []      # (due_step, request), submission order
        self.events: list = []

    # -- queue side ---------------------------------------------------------

    def submit(self, req: Request, now: int = 0) -> str:
        """Enqueue a request (FIFO; callers submit in arrival order).

        Against a full queue (``max_queue`` set) the request is deferred
        with exponential backoff while it has ``retries`` budget left,
        else rejected with ``REASON_OVER_BUDGET``.  Returns the resulting
        ``req.status`` (``"queued"`` / ``"deferred"`` / ``"rejected"``).
        """
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            if req.attempts < req.retries:
                self.defer(req, now)
            else:
                self.reject(req, REASON_OVER_BUDGET)
            return req.status
        req.status = "queued"
        self._queue.append(req)
        self.events.append(("submit", req.rid, req.arrival))
        return req.status

    def reject(self, req: Request, reason: str) -> None:
        """Mark a request unservable (e.g. prompt beyond slot capacity)."""
        req.status = "rejected"
        self.events.append(("reject", req.rid, reason))

    def defer(self, req: Request, now: int) -> None:
        """Park a queue-full submission for one exponential-backoff window:
        attempt ``a`` re-submits at ``now + backoff * 2**a`` — bounded,
        deterministic, and audited as ``("defer", rid, attempt, due)``."""
        delay = max(1, req.backoff) * (2 ** req.attempts)
        req.attempts += 1
        req.status = "deferred"
        self._retries.append((now + delay, req))
        self.events.append(("defer", req.rid, req.attempts, now + delay))

    def poll_retries(self, now: int) -> list:
        """Re-submit every deferred request whose backoff window has
        elapsed (``due <= now``), in original deferral order — each goes
        back through ``submit`` and may queue, defer again, or exhaust
        its budget and reject.  Returns the requests that rejected (the
        engine counts them)."""
        due = [(d, r) for d, r in self._retries if d <= now]
        if not due:
            return []
        self._retries = [(d, r) for d, r in self._retries if d > now]
        rejected = []
        for _, req in due:
            self.events.append(("retry", req.rid, req.attempts, now))
            if self.submit(req, now) == "rejected":
                rejected.append(req)
        return rejected

    def expire(self, now: int) -> list:
        """Sweep the queue for requests whose ``queue_ttl`` has lapsed
        (waited more than ``queue_ttl`` steps past ``arrival``); each is
        evicted in FIFO order with a typed audit event.  Returns the
        expired requests (the engine counts them)."""
        expired = []
        kept: deque = deque()
        for req in self._queue:
            if (req.queue_ttl is not None
                    and now - req.arrival > req.queue_ttl):
                req.status = "expired"
                self.events.append(
                    ("expire", req.rid, REASON_DEADLINE_EXPIRED, now))
                expired.append(req)
            else:
                kept.append(req)
        self._queue = kept
        return expired

    # -- slot side ----------------------------------------------------------

    def admit(self, now: int):
        """Admit the queue head into the lowest free slot, if both exist
        and the head has arrived (``arrival <= now``).  Returns
        ``(slot, request)`` or ``None``; loop until ``None`` to refill
        every free slot in one engine step.  Stamps ``admitted_at`` — the
        reference point for the engine's ``deadline_steps`` sweep."""
        free = next((i for i, r in enumerate(self._slots) if r is None),
                    None)
        if free is None or not self._queue:
            return None
        if self._queue[0].arrival > now:
            return None
        req = self._queue.popleft()
        req.status, req.slot = "running", free
        req.admitted_at = now
        self._slots[free] = req
        self.events.append(("admit", req.rid, free, now))
        return free, req

    def release(self, req: Request, status: str = "finished",
                reason: str | None = None) -> None:
        """Free a running request's slot and record why.  ``reason`` (a
        ``REASON_*`` tag) extends the audit event for fault evictions —
        deadline expiry, numerical quarantine — and is omitted from the
        event for plain finishes, keeping the seed event shape."""
        self._slots[req.slot] = None
        if reason is None:
            self.events.append((status, req.rid, req.slot))
        else:
            self.events.append((status, req.rid, req.slot, reason))
        req.status, req.slot = status, None

    # -- queries ------------------------------------------------------------

    def active(self):
        """Occupied slots as ``[(slot, request), ...]`` in slot order."""
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def has_work(self) -> bool:
        """True while anything is queued (even future arrivals), parked
        for retry, or live in a slot."""
        return (bool(self._queue) or bool(self._retries)
                or any(r is not None for r in self._slots))

    def queued(self) -> int:
        """Number of requests still waiting in the queue."""
        return len(self._queue)
