"""Slot-based paged KV / state cache for the continuous-batching engine.

The cache is one fixed-capacity pytree shared by every live request: each
request owns one *slot* (a batch row) of every leaf, so admitting or
evicting a request is a row write, never a reshape — the batched decode
step keeps one compiled executable for the whole engine lifetime.

Layout per family (``L`` = layer-stack dim, ``B`` = slot count, ``S`` =
slot sequence capacity):

* attention families (dense / moe / hybrid): ``k``/``v`` slot arrays
  ``(L, B, S, KV, hd)`` plus a per-entry position map ``pos (L, B, S)``.
  Entries never written hold :data:`INVALID_POS`, which fails the
  ``k_pos <= q_pos`` decode mask for every real query position — a slot's
  empty (or evicted) region can never attend, structurally.
* SSM families (ssm / hybrid): the per-layer decode state
  (``h (L, B, H, P, N)`` fp32 + ``conv (L, B, W-1, C)``), one batch row
  per slot.

Ring semantics match ``models.attention.mha_decode`` exactly, but per
slot: a request whose prefill produced ``cap`` cache entries (``cap =
min(prompt_len, sliding_window)``, the ``serve.engine._window_kv`` rule)
keeps position ``p`` at ring index ``p % cap`` — drop-oldest at fixed
shape.  Because each slot carries its own ``cap``, requests with
different prompt lengths decode bit-identically to N independent
``generate`` calls while sharing one launch.

Admission writes retrace only per *bucket shape* (the request's ``cap``);
same-length prompts reuse the compiled writer, and the batched decode
step never retraces at all (its shapes are fixed by ``(B, S)``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm as S

tmap = jax.tree_util.tree_map

# Sentinel for cache entries that were never written (or were invalidated
# by eviction): larger than any reachable token position, so the decode
# mask ``k_pos <= q_pos`` always rejects it.
INVALID_POS = 1 << 30


def slot_capacity(cfg: ArchConfig, prompt_len: int) -> int:
    """Ring capacity a request's slot needs — ``serve.engine._window_kv``'s
    effective prefill length: the sliding window when it is shorter than
    the prompt, else the full prompt."""
    W = cfg.sliding_window
    if W and W < prompt_len:
        return W
    return prompt_len


def init_slots(params, cfg: ArchConfig, n_slots: int, seq_cap: int,
               dtype=jnp.bfloat16):
    """Allocate the engine's slot cache: all-zero KV with every position
    :data:`INVALID_POS` (nothing attends), zero SSM state."""
    hd = cfg.hd
    n = cfg.n_layers
    fam = cfg.family

    def kv():
        return {"k": jnp.zeros((n, n_slots, seq_cap, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((n, n_slots, seq_cap, cfg.n_kv_heads, hd),
                               dtype),
                "pos": jnp.full((n, n_slots, seq_cap), INVALID_POS,
                                jnp.int32)}

    def ssm_state():
        one = S.ssm_state_init(
            tmap(lambda a: a[0], params["layers"]["ssm"]), n_slots,
            cfg.d_model, dtype)
        return tmap(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if fam in ("dense", "moe"):
        return {"kv": kv()}
    if fam == "ssm":
        return {"ssm": ssm_state()}
    if fam == "hybrid":
        return {"kv": kv(), "ssm": ssm_state()}
    raise NotImplementedError(
        f"family {fam!r} has no slot-cache layout (serving engine covers "
        "dense/moe/ssm/hybrid)")


@jax.jit
def _scatter_kv(kv, slot, k, v, pos):
    """Write one request's prefill KV (``k/v (L, 1, cap, KV, hd)``, ``pos
    (L, cap)``) into slot row ``slot``; the row's tail beyond ``cap`` is
    zeroed and its positions invalidated, so nothing from a previous
    occupant survives."""
    n, _, seq_cap = kv["pos"].shape
    cap = pos.shape[1]
    k_row = jnp.zeros(kv["k"].shape[:1] + kv["k"].shape[2:], kv["k"].dtype)
    v_row = jnp.zeros_like(k_row)
    k_row = k_row.at[:, :cap].set(k[:, 0].astype(k_row.dtype))
    v_row = v_row.at[:, :cap].set(v[:, 0].astype(v_row.dtype))
    p_row = jnp.full((n, seq_cap), INVALID_POS, jnp.int32)
    p_row = p_row.at[:, :cap].set(pos)
    return {"k": kv["k"].at[:, slot].set(k_row),
            "v": kv["v"].at[:, slot].set(v_row),
            "pos": kv["pos"].at[:, slot].set(p_row)}


@jax.jit
def _scatter_state(state, slot, st):
    """Write one request's prefill SSM state (leaves ``(L, 1, ...)``) into
    slot row ``slot``."""
    return tmap(lambda a, b: a.at[:, slot].set(b[:, 0].astype(a.dtype)),
                state, st)


@jax.jit
def _invalidate_kv(kv, slot):
    return dict(kv, pos=kv["pos"].at[:, slot].set(INVALID_POS))


def write_prefill(cache, slot: int, request_cache):
    """Graft a single request's ``serve.engine.prefill`` cache (batch 1)
    into slot ``slot``.  Retraces only per prefill *shape bucket* (the
    request's ring capacity); same-length prompts reuse the executable."""
    out = dict(cache)
    if "kv" in cache:
        rkv = request_cache["kv"]
        out["kv"] = _scatter_kv(cache["kv"], slot, rkv["k"], rkv["v"],
                                rkv["pos"])
    if "ssm" in cache:
        out["ssm"] = _scatter_state(cache["ssm"], slot,
                                    request_cache["ssm"])
    return out


def clear_slot(cache, slot: int):
    """Evict slot ``slot``: invalidate every cache position so the dead
    history can never attend into the slot's next occupant.  (Admission
    additionally zero-fills the row; this makes eviction safe even before
    reuse.)  SSM state needs no invalidation — it is overwritten wholesale
    at the next admission and free slots never feed real outputs."""
    out = dict(cache)
    if "kv" in cache:
        out["kv"] = _invalidate_kv(cache["kv"], slot)
    return out


def poison_slot(cache, slot: int, value=float("nan")):
    """Chaos-harness injector (``repro.testing.faults.nan_slot``): overwrite
    every FLOAT leaf of slot ``slot``'s cache row with ``value`` so the
    next batched decode produces non-finite logits for THAT slot only.

    Slots share weights, never activations — attention reads each slot's
    own KV row, SSM state is a per-slot row, and every row-wise op keeps
    batch rows independent — so the poison cannot leak into neighbors:
    the quarantine bit-identity test relies on exactly this.  Integer
    leaves (the validity positions) are left alone; both are restored by
    the full row overwrite at the slot's next admission."""
    def bad(a):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.at[:, slot].set(value)
    return tmap(bad, cache)
