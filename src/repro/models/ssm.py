"""Mamba-2 SSD (state-space duality) mixer — chunked quadratic-within-chunk /
linear-across-chunks algorithm, plus the O(1)-state decode step used for the
``decode_32k`` / ``long_500k`` shapes (sub-quadratic: state is seq-independent).

Pruning applicability (paper §5.2.4 analogue, see DESIGN.md): in/out
projections are block-based-prunable FC layers; the depthwise conv1d and the
small SSD parameters (A, D, dt bias) are never pruned.

Sparse serving: both projections go through ``layers.linear``, so when
``serve.compile.compile_model`` installs a ``core.packed.PackedLayout``
next to ``in_proj``/``out_proj`` (stacked over the scanned layer axis) they
dispatch through ``kernels.ops.sparse_linear`` — the Pallas BCS kernel —
in both the full-sequence mixer and the O(1)-state decode step.  The
in_proj covers the z (gate), xBC, and dt streams in one GEMM, so packing it
sparsifies all three at once.  ``_dims`` reads layer geometry from either
the dense weight or the layout, so ``keep_dense=False`` serving works.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module as M
from repro.models import layers as L


def ssm_init(key, d_model, d_state, headdim=64, expand=2, conv_width=4,
             n_groups=1, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_dim = d_inner + 2 * n_groups * d_state
    ks = M.split_keys(key, ["in_proj", "conv", "out_proj", "A", "dt"])
    proj_out = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return {
        "in_proj": L.linear_init(ks["in_proj"], d_model, proj_out, dtype),
        "conv": L.conv1d_init(ks["conv"], conv_dim, conv_width, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": L.rmsnorm_init(None, d_inner, dtype),
        "out_proj": L.linear_init(ks["out_proj"], d_inner, d_model, dtype),
    }


def _proj_kn(p):
    """(K, N) of a projection node, from the dense weight or — when
    ``compile_model(keep_dense=False)`` dropped "w" — the packed layout's
    static shape (identical by construction)."""
    w = p.get("w")
    return tuple(w.shape[-2:]) if w is not None else tuple(p["packed"].shape)


def _dims(params, d_model):
    d_inner = _proj_kn(params["out_proj"])[0]
    n_heads = params["A_log"].shape[0]
    headdim = d_inner // n_heads
    conv_dim = params["conv"]["w"].shape[1]
    d_state = (conv_dim - d_inner) // 2  # n_groups == 1
    return d_inner, n_heads, headdim, d_state


def _segsum(x):
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums (log-decay)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_scan(xh, dt, A, Bm, Cm, chunk=64):
    """Chunked SSD.  xh (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm, Cm (B,S,H,N) (groups already broadcast).  Returns (B,S,H,P) and the
    final state (B,H,P,N)."""
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    c = S // chunk

    def r(t, *tail):  # (B,S,...) -> (B,c,chunk,...)
        return t.reshape(B, c, chunk, *tail)

    xc = r(xh, H, Pd).astype(jnp.float32)
    dtc = r(dt, H).astype(jnp.float32)
    Bc = r(Bm, H, N).astype(jnp.float32)
    Cc = r(Cm, H, N).astype(jnp.float32)

    dA = dtc * A  # (B,c,Q,H)
    dA = dA.transpose(0, 1, 3, 2)               # (B,c,H,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)

    Ldec = jnp.exp(_segsum(dA))                 # (B,c,H,Q,Q)
    xdt = xc * dtc[..., None]                   # (B,c,Q,H,P)
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", Cc, Bc, Ldec, xdt)

    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)   # (B,c,H,Q)
    states = jnp.einsum("bckhn,bchk,bckhp->bchpn", Bc, decay_states, xdt)
    chunk_decay = jnp.exp(dA_cs[..., -1])             # (B,c,H)

    def body(h, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                # emit state ENTERING chunk

    h0 = jnp.zeros((B, H, Pd, N), jnp.float32)
    h_last, prev_states = jax.lax.scan(
        body, h0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,c,H,P,N)

    state_decay = jnp.exp(dA_cs)                        # (B,c,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(B, S, H, Pd)
    return y, h_last


def ssm(params, x, *, masks=None, dist=None, chunk=64):
    """Full-sequence mamba2 mixer.  x: (B,S,D) -> (B,S,D), plus the decode
    state dict {h: (B,H,P,N) f32, conv: (B,width-1,conv_dim)} — conv holds
    the last pre-conv inputs so a following decode step sees the exact
    causal-conv window."""
    m = masks or {}
    B, S, D = x.shape
    d_inner, H, Pd, N = _dims(params, D)
    width = params["conv"]["w"].shape[0]
    zxbcdt = L.linear(params["in_proj"], x, m.get("in_proj"))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    conv_tail = xbc[:, max(S - (width - 1), 0):, :]
    if S < width - 1:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (width - 1 - S, 0), (0, 0)))
    xbc = L.causal_conv1d(params["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    xh, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xh = xh.reshape(B, S, H, Pd)
    Bm = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    Cm = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if dist is not None:
        xh = dist.shard_heads(xh)
    y, h_last = _ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk)
    y = y + xh.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.linear(params["out_proj"], y, m.get("out_proj"))
    return out, {"h": h_last, "conv": conv_tail}


def ssm_decode(params, x, state, *, masks=None, dist=None):
    """One-token decode.  state = dict(h=(B,H,P,N) f32, conv=(B,W-1,Cdim))."""
    m = masks or {}
    B, _, D = x.shape
    d_inner, H, Pd, N = _dims(params, D)
    zxbcdt = L.linear(params["in_proj"], x[:, 0, :], m.get("in_proj"))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    conv_state, xbc = L.conv1d_step(params["conv"], state["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    xh, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xh = xh.reshape(B, H, Pd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                               # (B,H)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    h = state["h"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bf)
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) + xh * params["D"][:, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = L.linear(params["out_proj"], y, m.get("out_proj"))
    return out[:, None, :], {"h": h, "conv": conv_state}


def ssm_state_init(params, batch, d_model, dtype=jnp.bfloat16):
    d_inner, H, Pd, N = _dims(params, d_model)
    conv_dim = params["conv"]["w"].shape[1]
    width = params["conv"]["w"].shape[0]
    return {"h": jnp.zeros((batch, H, Pd, N), jnp.float32),
            "conv": jnp.zeros((batch, width - 1, conv_dim), dtype)}
