"""Mixture-of-Experts FFN with capacity-based one-hot dispatch (GShard-style).

Sharding: experts sharded over the model axis when n_experts % tp == 0
(kimi-k2: 384/16 = 24 experts/shard, EP) else the expert hidden dim is
tensor-parallel (mixtral: 8 experts, d_ff 14336/16).  The dispatch einsum
resharding (tokens data-sharded -> experts model-sharded) is GSPMD's
all-to-all — the paper's per-expert block pruning shrinks exactly this
expert-side compute and the expert weight footprint.

Sparse serving: when ``serve.compile.compile_model`` installs a
``core.packed.PackedLayout`` next to an expert weight
(``params[name]["packed"]``, leading expert axis on every leaf), the three
expert GEMMs (gate/up/down) execute through
``kernels.ops.sparse_expert_linear`` — the Pallas BCS kernel vmapped over
experts — instead of the dense masked einsum; silu fuses into the gate
projection's epilogue exactly as in ``layers.ffn``.

Router stays dense and fp32 — the LM-family analogue of the paper's
"don't prune tiny, sensitive layers" depthwise rule (§5.2.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module as M


def moe_init(key, d_model, d_ff, n_experts, dtype=jnp.bfloat16):
    ks = M.split_keys(key, ["router", "gate", "up", "down"])
    return {
        "router": {"w": M.dense_init(ks["router"], (d_model, n_experts),
                                     jnp.float32)},
        "gate": {"w": M.dense_init(ks["gate"], (n_experts, d_model, d_ff), dtype)},
        "up": {"w": M.dense_init(ks["up"], (n_experts, d_model, d_ff), dtype)},
        "down": {"w": M.dense_init(ks["down"], (n_experts, d_ff, d_model), dtype)},
    }


def _dispatch_tensors(logits, top_k, capacity):
    """logits (G,S,E) -> dispatch (G,S,E,C) one-hot-ish, combine (G,S,E,C).

    Logits are normalized to fp32 up front so externally supplied bf16
    logits can't shift the softmax/top_k expert choice (``moe()`` itself
    always routes in fp32; the one-hots and cumsum slot positions were
    already built in explicit fp32 below)."""
    logits = logits.astype(jnp.float32)
    G, S, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)             # (G,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    se_oh = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2)  # (G,S,E)
    pos = jnp.cumsum(se_oh, axis=1) * se_oh - 1.0            # (G,S,E) slot index
    keep = (pos >= 0) & (pos < capacity)
    disp = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                          dtype=jnp.float32) * keep[..., None]  # (G,S,E,C)
    weight_se = jnp.einsum("gske,gsk->gse",
                           jax.nn.one_hot(idx, E, dtype=jnp.float32),
                           gate_vals)
    combine = disp * weight_se[..., None]
    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    frac_tokens = jnp.mean(se_oh, axis=(0, 1)) / top_k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return disp, combine, aux


def _expert_linear(p, x, mask=None, act="none"):
    """Per-expert projection: x (G,E,C,din) @ w (E,din,dout) -> (G,E,C,dout).

    Dispatches through the batched sparse kernel when the compiler
    installed a ``PackedLayout`` (``p["packed"]``, leading expert axis);
    otherwise the dense masked einsum.  ``act`` fuses into the packed
    kernel's epilogue; on the dense path it applies after the einsum —
    same math (under bf16 the fused path rounds once instead of twice,
    ~1 ulp, exactly as documented for ``layers.ffn``).  A
    ``core.packed.DegradedLayer`` sentinel (layout failed validation)
    routes to the dense masked einsum — see ``layers.linear``."""
    from repro.core.packed import DegradedLayer
    packed = p.get("packed")
    if isinstance(packed, DegradedLayer):
        packed = None                    # validated-corrupt: masked-dense
    if packed is not None:
        from repro.kernels import ops  # late import: kernels -> core only
        G, E, C, din = x.shape
        xe = x.transpose(1, 0, 2, 3).reshape(E, G * C, din)
        ye = ops.sparse_expert_linear(xe, packed, act=act)
        return ye.reshape(E, G, C, -1).transpose(1, 0, 2, 3)
    w = p["w"]
    if mask is not None:
        w = w * mask.astype(w.dtype)
    y = jnp.einsum("gecd,edf->gecf", x, w)
    if act == "silu":
        y = jax.nn.silu(y)
    return y


def moe(params, x, *, top_k, capacity_factor=1.25, group=1024,
        masks=None, dist=None):
    """x: (B,S,D) -> (B,S,D), aux_loss.  Tokens regrouped to bound the
    dispatch tensor to (G, group, E, C)."""
    m = masks or {}
    B, S, D = x.shape
    E = params["router"]["w"].shape[-1]
    T = B * S
    Sg = min(group, T)
    G = T // Sg
    xt = x.reshape(G, Sg, D)
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"]["w"])
    # the group-size clamp must stay OUTSIDE the floor of 4: on tiny
    # groups (Sg < 4) the floor alone would hand _dispatch_tensors a
    # capacity beyond the group size (locked by a regression test)
    C = min(Sg, max(4, int(Sg * top_k / E * capacity_factor)))
    disp, combine, aux = _dispatch_tensors(logits, top_k, C)

    dt = x.dtype
    expert_in = jnp.einsum("gsec,gsd->gecd", disp.astype(dt), xt)  # a2a here
    if dist is not None:
        expert_in = dist.shard_experts(expert_in)

    g = _expert_linear(params["gate"], expert_in, m.get("gate"), act="silu")
    u = _expert_linear(params["up"], expert_in, m.get("up"))
    expert_out = _expert_linear(params["down"], g * u, m.get("down"))
    out = jnp.einsum("gecd,gsec->gsd", expert_out, combine.astype(dt))
    return out.reshape(B, S, D), aux
