"""Minimal pure-JAX module utilities: param init, path-rule sharding specs.

No flax/haiku in this environment; models are (init, apply) function pairs over
nested-dict param pytrees.  Sharding is assigned by *path pattern rules* so one
table per architecture family keeps every param's PartitionSpec in one place.
"""
from __future__ import annotations

import re
from typing import Any, Iterable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict of jnp arrays


def dense_init(key, shape, dtype=jnp.bfloat16, scale: float | None = None):
    """Truncated-normal (fan-in) init used for all projection matrices."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.bfloat16):
    return jnp.ones(shape, dtype)


def split_keys(key, names: Iterable[str]) -> dict:
    names = list(names)
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Path-rule sharding
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_from_rules(params: Params, rules: list[tuple[str, P]],
                    default: P = P()) -> Params:
    """Build a PartitionSpec pytree matching ``params`` from (regex, spec) rules.

    The first matching rule wins.  Specs are right-aligned to the array rank:
    a rule spec ``P('data', 'model')`` applied to a rank-3 (scanned) param
    becomes ``P(None, 'data', 'model')``.
    """
    compiled = [(re.compile(pat), spec) for pat, spec in rules]

    def assign(path, leaf):
        s = path_str(path)
        for pat, spec in compiled:
            if pat.search(s):
                pad = leaf.ndim - len(spec)
                if pad < 0:  # spec longer than rank: trim leading entries
                    return P(*spec[-leaf.ndim:])
                return P(*([None] * pad + list(spec)))
        pad = leaf.ndim - len(default)
        return P(*([None] * max(pad, 0) + list(default)))

    return jax.tree_util.tree_map_with_path(assign, params)


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def tree_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
