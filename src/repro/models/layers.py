"""Shared layer primitives: RMSNorm, rotary embeddings, (maskable) linear,
embedding table, cross-entropy.  All ops are plain jnp so GSPMD partitions them
under pjit; sparsity enters either as a multiplicative mask (training path) or
through the BCS Pallas kernel (serving path, see repro.kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packed import DegradedLayer
from repro.models import module as M


# -- RMSNorm ----------------------------------------------------------------

def rmsnorm_init(key, dim, dtype=jnp.bfloat16):
    del key
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# -- Rotary -----------------------------------------------------------------

def rotary_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rotary(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rotary_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- Linear (dense, masked-sparse, or packed BCS-sparse) ---------------------

def linear_init(key, in_dim, out_dim, dtype=jnp.bfloat16, bias=False):
    p = {"w": M.dense_init(key, (in_dim, out_dim), dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def _apply_act(y, act):
    if act == "silu":
        return jax.nn.silu(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    return y


def linear(params, x, mask=None, act="none"):
    """y = act(x @ W + b) through whichever executor applies.

    If the layer carries a packed BCS layout (``params["packed"]``, a
    ``core.packed.PackedLayout`` installed by
    ``repro.serve.compile.compile_model``) the Pallas block-sparse kernel
    executes it — one launch per degree bin, bias + activation fused into
    the epilogue, outputs gathered back to original column order when the
    layout was row-reordered; any ``mask`` is ignored there (it was baked
    in at pack time).  Otherwise a dense einsum runs, with an optional
    pruning ``mask`` broadcastable to w (XLA fuses the multiply into the
    matmul operand).

    A ``core.packed.DegradedLayer`` sentinel (left by
    ``serve.compile.degrade_invalid_layers`` where a layout failed
    validation) routes to the dense einsum: the retained ``w`` carries the
    pruning zeros, so the fallback is masked-dense — slower, never wrong.
    """
    packed = params.get("packed")
    if isinstance(packed, DegradedLayer):
        packed = None                    # validated-corrupt: masked-dense
    if packed is not None:
        from repro.kernels import ops  # late import: kernels -> core only
        return ops.sparse_linear(x, packed=packed, bias=params.get("b"),
                                 act=act)
    w = params["w"]
    if mask is not None:
        w = w * mask.astype(w.dtype)
    y = jnp.einsum("...i,io->...o", x, w)
    if "b" in params:
        y = y + params["b"]
    return _apply_act(y, act)


# -- Embedding ---------------------------------------------------------------

def embedding_init(key, vocab, dim, dtype=jnp.bfloat16):
    return {"table": M.embed_init(key, (vocab, dim), dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Logits against the (separate) output head table: (..., d) -> (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


# -- Loss ---------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy.  logits (..., vocab) maybe vocab-sharded —
    written with plain reductions so GSPMD inserts the vocab all-reduce."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# -- SwiGLU FFN ---------------------------------------------------------------

def ffn_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = M.split_keys(key, ["gate", "up", "down"])
    return {
        "gate": linear_init(ks["gate"], d_model, d_ff, dtype),
        "up": linear_init(ks["up"], d_model, d_ff, dtype),
        "down": linear_init(ks["down"], d_ff, d_model, dtype),
    }


def ffn(params, x, masks=None):
    """SwiGLU: silu is requested as the gate projection's epilogue so the
    packed-BCS path fuses it into the kernel's final store.  Same math, but
    under bf16 the fused path applies silu to the fp32 accumulator BEFORE
    the output rounding (one rounding instead of two) — packed and dense
    outputs may differ by ~1 bf16 ulp; in fp32 they agree tightly."""
    m = masks or {}
    g = linear(params["gate"], x, m.get("gate"), act="silu")
    u = linear(params["up"], x, m.get("up"))
    return linear(params["down"], g * u, m.get("down"))


# -- Depthwise causal conv1d (mamba/hymba mixers; NOT pruned per paper §5.2.4) --

def conv1d_init(key, channels, width, dtype=jnp.bfloat16):
    return {"w": M.dense_init(key, (width, channels), dtype, scale=width ** -0.5)}


def causal_conv1d(params, x):
    """x: (batch, seq, channels) depthwise causal conv."""
    w = params["w"]                              # (width, C)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is tiny (4); unrolled taps fuse into one op
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def conv1d_step(params, state, x_t):
    """Single decode step. state: (batch, width-1, C); x_t: (batch, C)."""
    w = params["w"]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)   # (b, width, C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return window[:, 1:, :], out
