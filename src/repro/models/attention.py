"""GQA attention: flash-style KV-chunked online softmax (training/prefill),
direct cached-decode step, sliding window, and cross-attention.

Sharding modes (set per arch in configs, see DESIGN.md §5):
  - "heads": q heads sharded over the model axis (kv replicated when
    n_kv % tp != 0) — the default TP layout.
  - "seq":   query sequence sharded over the model axis (context parallel) —
    used when n_heads % tp != 0 (phi3: 40H, hymba: 25H).
Decode KV caches are sequence-sharded over the model axis universally.

Sparse serving: all four projections (wq/wk/wv/wo) dispatch through
``layers.linear``, so layers compiled by ``repro.serve.compile`` carry packed
BCS weights and execute on the Pallas block-sparse kernel transparently; the
training-time pruning masks are baked into the packed layout and dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module as M
from repro.models import layers as L

NEG_INF = -1e30


def _proj(params, name, x, masks):
    """One attention projection.  ``layers.linear`` owns the dispatch:
    packed BCS layers route to the sparse kernel (and ignore the mask —
    it is baked into the layout); dense layers apply it."""
    return L.linear(params[name], x, masks.get(name))


def attn_init(key, d_model, n_heads, n_kv, head_dim, dtype=jnp.bfloat16,
              qkv_bias=False):
    ks = M.split_keys(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": L.linear_init(ks["wq"], d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "wk": L.linear_init(ks["wk"], d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "wv": L.linear_init(ks["wv"], d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "wo": L.linear_init(ks["wo"], n_heads * head_dim, d_model, dtype),
    }


def _grouped(q, n_kv):
    """(B,S,H,hd) -> (B,S,KV,G,hd) — decode path only (heads unsharded
    there; a head-sharded dim cannot be reshaped into (KV, G) under GSPMD
    without full rematerialization, so the training path stays in H-form)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def _expand_kv(k, n_heads):
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating each KV head G times.  Keeps
    every attention tensor in H-form so the model-axis head sharding is
    preserved end to end (perf iteration 1, EXPERIMENTS.md §Perf)."""
    B, S, KV, hd = k.shape
    G = n_heads // KV
    if G == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, hd))
    return k.reshape(B, S, n_heads, hd)


def attend(q, k, v, q_pos, k_pos, causal=True, window=0, kv_chunk=1024):
    """Flash-style online-softmax attention, scanned over KV chunks.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) (KV already expanded);
    positions int32.  Returns (B, Sq, H, hd).  Memory is bounded by one
    (B, H, Sq, kv_chunk) score tile instead of the full Sq×Sk matrix.
    A single chunk (kv_chunk >= Sk) skips the scan entirely — cheaper for
    GSPMD (no carry resharding), used for the 4k training shapes."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    kv_chunk = min(kv_chunk, Sk)
    n_chunks = Sk // kv_chunk
    assert Sk % kv_chunk == 0, (Sk, kv_chunk)

    qf = q.astype(jnp.float32) * scale

    if n_chunks == 1:
        s = jnp.einsum("bqhe,bshe->bhqs", qf, k.astype(jnp.float32))
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqs,bshe->bqhe", p, v.astype(jnp.float32))
        return out.astype(q.dtype)

    kc = k.reshape(B, n_chunks, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, kv_chunk)

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)

    def body(carry, chunk):
        m, l, acc = carry
        kj, vj, pj = chunk
        s = jnp.einsum("bqhe,bshe->bhqs", qf, kj.astype(jnp.float32))
        mask = jnp.ones((Sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= pj[None, :]
        if window > 0:
            mask &= pj[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshe->bhqe", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)      # (B,Sq,H,hd)


def attend_cached(q, k_cache, v_cache, q_pos, k_pos, window=0):
    """Single-token decode over an S-sharded KV cache — direct softmax; GSPMD
    emits the cross-shard max/sum all-reduces for the sharded Sk dim.

    q: (B, 1, KV, G, hd); caches: (B, Sk, KV, hd).  Positions come either
    batch-shared (``q_pos (Q,)``, ``k_pos (Sk,)`` — the single-sequence
    ``generate`` path) or per-slot ragged (``q_pos (B, Q)``, ``k_pos
    (B, Sk)`` — the continuous-batching engine, where every slot holds a
    different history length; never-written entries carry
    ``serve.kvcache.INVALID_POS`` so they fail the causal mask).  The
    masked-softmax math is identical elementwise, so a ragged batch stays
    bit-identical per slot to the shared-position B=1 decode."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32) * hd ** -0.5,
                   k_cache.astype(jnp.float32))
    if k_pos.ndim == 1:
        mask = k_pos[None, :] <= q_pos[:, None]                  # (Q, Sk)
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
    else:
        mask = k_pos[:, None, :] <= q_pos[:, :, None]            # (B, Q, Sk)
        if window > 0:
            mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def mha(params, x, positions, n_heads, n_kv, head_dim, *, causal=True,
        window=0, rope_theta=10000.0, masks=None, dist=None, shard="heads",
        memory=None, kv_chunk=1024):
    """Full-sequence attention (training / prefill).  If ``memory`` is given,
    performs cross-attention against it (no causal mask, no rope on kv)."""
    m = masks or {}
    B, S, _ = x.shape
    q = _proj(params, "wq", x, m).reshape(B, S, n_heads, head_dim)
    src = memory if memory is not None else x
    Sk = src.shape[1]
    k = _proj(params, "wk", src, m).reshape(B, Sk, n_kv, head_dim)
    v = _proj(params, "wv", src, m).reshape(B, Sk, n_kv, head_dim)

    if memory is None:
        q = L.apply_rotary(q, positions, rope_theta)
        k = L.apply_rotary(k, positions, rope_theta)
        k_pos = positions
        causal_ = causal
    else:
        k_pos = jnp.arange(Sk, dtype=jnp.int32)
        causal_ = False

    if dist is not None:
        # gather/shard K,V in compact KV-form BEFORE head expansion
        k = dist.shard_attn_kv(k, shard, n_kv)
        v = dist.shard_attn_kv(v, shard, n_kv)
    kf = _expand_kv(k, n_heads)
    vf = _expand_kv(v, n_heads)
    if dist is not None:
        q = dist.shard_attn_q(q, shard)
        if dist.mode != "fsdp" and shard == "heads":
            kf = dist.shard_attn_q(kf, shard)  # H-form TP head sharding
            vf = dist.shard_attn_q(vf, shard)

    out = attend(q, kf, vf, positions, k_pos,
                 causal=causal_, window=window, kv_chunk=kv_chunk)
    out = out.reshape(B, S, n_heads * head_dim)
    return _proj(params, "wo", out, m), (k, v)


def mha_decode(params, x, cache, pos, n_heads, n_kv, head_dim, *,
               window=0, rope_theta=10000.0, masks=None, dist=None):
    """One-token decode.  cache = dict(k=(B,S,KV,hd), v=..., ) already holding
    ``S`` tokens; the new token attends over the cache plus itself written in.
    Returns (out, cache) — cache is rolled (drop-oldest) to stay fixed-shape.
    """
    m = masks or {}
    B, _, _ = x.shape
    q = _proj(params, "wq", x, m).reshape(B, 1, n_heads, head_dim)
    k = _proj(params, "wk", x, m).reshape(B, 1, n_kv, head_dim)
    v = _proj(params, "wv", x, m).reshape(B, 1, n_kv, head_dim)
    q = L.apply_rotary(q, pos, rope_theta)
    k = L.apply_rotary(k, pos, rope_theta)

    S = cache["k"].shape[1]
    # Fixed-shape ring update: overwrite slot pos % S (positions track validity).
    slot = (pos[0, 0] % S).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    k_pos = jax.lax.dynamic_update_slice(cache["pos"], pos[0], (slot,))
    if dist is not None:
        k_cache = dist.shard_cache(k_cache)
        v_cache = dist.shard_cache(v_cache)

    out = attend_cached(_grouped(q, n_kv), k_cache, v_cache, pos[:, 0:1][0],
                        k_pos, window=window)
    out = out.reshape(B, 1, n_heads * head_dim)
    y = _proj(params, "wo", out, m)
    return y, {"k": k_cache, "v": v_cache, "pos": k_pos}


def mha_decode_ragged(params, x, cache, pos, cap, n_heads, n_kv, head_dim, *,
                      window=0, rope_theta=10000.0, masks=None, dist=None):
    """One-token decode across RAGGED slot histories (continuous batching).

    Unlike ``mha_decode`` — which assumes every batch row sits at the same
    position — each slot ``b`` carries its own position ``pos[b]`` and its
    own ring capacity ``cap[b]`` (the request's effective prefill length,
    see ``serve.kvcache``).  cache: ``k/v (B, S, KV, hd)`` slot arrays and
    a per-entry position map ``pos (B, S)``; the new token writes ring
    index ``pos[b] % cap[b]`` of row ``b`` — the same fixed-shape
    drop-oldest rule ``mha_decode`` applies, so each slot's outputs are
    bit-identical to a B=1 ``mha_decode`` sequence over the same request.
    Entries beyond a slot's capacity keep ``INVALID_POS`` and never pass
    the causal mask.  Returns (out, cache).
    """
    m = masks or {}
    B, _, _ = x.shape
    q = _proj(params, "wq", x, m).reshape(B, 1, n_heads, head_dim)
    k = _proj(params, "wk", x, m).reshape(B, 1, n_kv, head_dim)
    v = _proj(params, "wv", x, m).reshape(B, 1, n_kv, head_dim)
    q = L.apply_rotary(q, pos, rope_theta)
    k = L.apply_rotary(k, pos, rope_theta)

    slots = (pos[:, 0] % jnp.maximum(cap, 1)).astype(jnp.int32)     # (B,)
    upd = jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (s, 0, 0)))
    k_cache = upd(cache["k"], k.astype(cache["k"].dtype), slots)
    v_cache = upd(cache["v"], v.astype(cache["v"].dtype), slots)
    k_pos = jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (s,)))(
        cache["pos"], pos[:, :1], slots)
    if dist is not None:
        k_cache = dist.shard_cache(k_cache)
        v_cache = dist.shard_cache(v_cache)

    out = attend_cached(_grouped(q, n_kv), k_cache, v_cache, pos, k_pos,
                        window=window)
    out = out.reshape(B, 1, n_heads * head_dim)
    y = _proj(params, "wo", out, m)
    return y, {"k": k_cache, "v": v_cache, "pos": k_pos}
