"""Model assembly for all assigned architecture families.

Layer stacks are `lax.scan`-ed over vmapped-stacked per-layer params to keep
the HLO size O(1) in depth — essential for the 512-device dry-run compiles.
Heterogeneous stacks (vlm cross-attn every k layers) scan over homogeneous
*groups*.  Decode paths thread per-layer caches through the same scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import module as M
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.moe import moe, moe_init


# ---------------------------------------------------------------------------
# Per-layer init/apply by family
# ---------------------------------------------------------------------------

def maybe_scan(body, carry, xs, unroll=False):
    """lax.scan, or an unrolled Python loop when ``unroll`` (the dry-run's
    cost-probe mode: XLA cost analysis counts while-loop bodies once)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _layer_init(key, cfg: ArchConfig, kind: str):
    ks = M.split_keys(key, ["a", "b", "c", "d", "e", "f"])
    hd = cfg.hd
    if kind == "dense":
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "attn": A.attn_init(ks["a"], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
                "ln2": L.rmsnorm_init(None, cfg.d_model),
                "ffn": L.ffn_init(ks["b"], cfg.d_model, cfg.d_ff)}
    if kind == "moe":
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "attn": A.attn_init(ks["a"], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
                "ln2": L.rmsnorm_init(None, cfg.d_model),
                "moe": moe_init(ks["b"], cfg.d_model, cfg.d_ff, cfg.n_experts)}
    if kind == "ssm":
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "ssm": S.ssm_init(ks["a"], cfg.d_model, cfg.ssm_state,
                                  headdim=cfg.ssm_headdim,
                                  expand=cfg.ssm_expand)}
    if kind == "hybrid":  # hymba: parallel attn + ssm heads, then FFN
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "attn": A.attn_init(ks["a"], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
                "ssm": S.ssm_init(ks["b"], cfg.d_model, cfg.ssm_state,
                                  headdim=cfg.ssm_headdim,
                                  expand=cfg.ssm_expand),
                "ln2": L.rmsnorm_init(None, cfg.d_model),
                "ffn": L.ffn_init(ks["c"], cfg.d_model, cfg.d_ff)}
    if kind == "cross":  # vlm cross-attn layer (own ffn, llama-vision style)
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "xattn": A.attn_init(ks["a"], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, hd),
                "gate": jnp.zeros((1,), jnp.float32),
                "ln2": L.rmsnorm_init(None, cfg.d_model),
                "ffn": L.ffn_init(ks["b"], cfg.d_model, cfg.d_ff)}
    if kind == "xdec":  # enc-dec decoder layer: self + cross + ffn
        return {"ln1": L.rmsnorm_init(None, cfg.d_model),
                "attn": A.attn_init(ks["a"], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
                "lnx": L.rmsnorm_init(None, cfg.d_model),
                "xattn": A.attn_init(ks["b"], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, hd),
                "ln2": L.rmsnorm_init(None, cfg.d_model),
                "ffn": L.ffn_init(ks["c"], cfg.d_model, cfg.d_ff)}
    raise ValueError(kind)


def _stack_init(key, cfg, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _layer_init(k, cfg, kind))(keys)


def _layer_fwd(p, x, positions, cfg: ArchConfig, kind, *, dist=None,
               memory=None, collect_cache=False):
    """Returns (x, aux, cache_kv) for one layer."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("dense", "moe", "hybrid", "xdec"):
        h = L.rmsnorm(p["ln1"], x)
        att, kv = A.mha(p["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads,
                        cfg.hd, window=cfg.sliding_window,
                        rope_theta=cfg.rope_theta, dist=dist,
                        shard=cfg.attn_shard, kv_chunk=cfg.kv_chunk)
        if kind == "hybrid":
            sm, _ = S.ssm(p["ssm"], h, dist=dist)
            att = (att + sm) * 0.5
        x = x + att
        if collect_cache:
            cache = {"k": kv[0], "v": kv[1]}
        if kind == "xdec":
            h = L.rmsnorm(p["lnx"], x)
            xa, xkv = A.mha(p["xattn"], h, positions, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd, dist=dist,
                            shard=cfg.attn_shard, memory=memory)
            x = x + xa
            if collect_cache:
                cache.update({"xk": xkv[0], "xv": xkv[1]})
        h = L.rmsnorm(p["ln2"], x)
        if kind == "moe":
            f, aux = moe(p["moe"], h, top_k=cfg.top_k, group=cfg.moe_group,
                         dist=dist)
        else:
            f = L.ffn(p["ffn"], h)
        x = x + f
    elif kind == "ssm":
        h = L.rmsnorm(p["ln1"], x)
        sm, _ = S.ssm(p["ssm"], h, dist=dist)
        x = x + sm
    elif kind == "cross":
        h = L.rmsnorm(p["ln1"], x)
        xa, _ = A.mha(p["xattn"], h, positions, cfg.n_heads, cfg.n_kv_heads,
                      cfg.hd, dist=dist, shard=cfg.attn_shard, memory=memory)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * xa
        h = L.rmsnorm(p["ln2"], x)
        x = x + L.ffn(p["ffn"], h)
    else:
        raise ValueError(kind)
    if dist is not None:
        x = dist.shard_residual(x)
    return x, aux, cache


def _scan_stack(stacked, x, positions, cfg, kind, *, dist=None, memory=None,
                remat=True):
    def body(carry, lp):
        h, aux = carry
        h, a, _ = _layer_fwd(lp, h, positions, cfg, kind, dist=dist,
                             memory=memory)
        return (h, aux + a), None

    if remat and cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = maybe_scan(body, (x, jnp.zeros((), jnp.float32)), stacked,
                             cfg.unroll_layers)
    return x, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ArchConfig):
    ks = M.split_keys(key, ["embed", "head", "stack", "enc", "cross"])
    params = {
        "embed": L.embedding_init(ks["embed"], cfg.vocab, cfg.d_model),
        "head": L.embedding_init(ks["head"], cfg.vocab, cfg.d_model),
        "norm_f": L.rmsnorm_init(None, cfg.d_model),
    }
    fam = cfg.family
    if fam in ("dense", "moe", "ssm", "hybrid"):
        params["layers"] = _stack_init(ks["stack"], cfg, fam, cfg.n_layers)
    elif fam == "encdec":
        params["enc"] = _stack_init(ks["enc"], cfg, "dense", cfg.n_enc_layers)
        params["dec"] = _stack_init(ks["stack"], cfg, "xdec", cfg.n_layers)
        params["norm_e"] = L.rmsnorm_init(None, cfg.d_model)
    elif fam == "vlm":
        k = cfg.cross_attn_interval
        n_groups = cfg.n_layers // k
        keys = jax.random.split(ks["stack"], n_groups)

        def group_init(gk):
            g1, g2 = jax.random.split(gk)
            return {"selfs": _stack_init(g1, cfg, "dense", k - 1),
                    "cross": _layer_init(g2, cfg, "cross")}
        params["groups"] = jax.vmap(group_init)(keys)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens, *, frontend=None, dist=None,
            positions=None):
    """tokens (B,S) -> logits (B,S,vocab).  ``frontend`` is the precomputed
    audio-frame / image-patch embedding stand-in (B, T, d_model) for
    encdec/vlm archs (modality frontends are stubs per the assignment)."""
    B, Sq = tokens.shape
    if positions is None:
        positions = jnp.arange(Sq, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = dist.shard_activations(x)
    fam = cfg.family
    if fam in ("dense", "moe", "ssm", "hybrid"):
        x, aux = _scan_stack(params["layers"], x, positions, cfg, fam,
                             dist=dist)
    elif fam == "encdec":
        enc_pos = jnp.arange(frontend.shape[1], dtype=jnp.int32)
        # bidirectional encoder over the frontend embeddings
        def enc_body(carry, lp):
            h, = carry
            hn = L.rmsnorm(lp["ln1"], h)
            att, _ = A.mha(lp["attn"], hn, enc_pos, cfg.n_heads,
                           cfg.n_kv_heads, cfg.hd, causal=False, dist=dist,
                           shard=cfg.attn_shard)
            h = h + att
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            if dist is not None:
                h = dist.shard_residual(h)
            return (h,), None
        if cfg.remat == "full":
            enc_body = jax.checkpoint(enc_body, prevent_cse=False)
        (memory,), _ = maybe_scan(enc_body, (frontend.astype(x.dtype),),
                                  params["enc"], cfg.unroll_layers)
        memory = L.rmsnorm(params["norm_e"], memory)
        x, aux = _scan_stack(params["dec"], x, positions, cfg, "xdec",
                             dist=dist, memory=memory)
    elif fam == "vlm":
        memory = frontend.astype(x.dtype)

        def group_body(carry, gp):
            h, aux = carry
            h, a1 = _scan_stack(gp["selfs"], h, positions, cfg, "dense",
                                dist=dist, remat=False)
            h, a2, _ = _layer_fwd(gp["cross"], h, positions, cfg, "cross",
                                  dist=dist, memory=memory)
            return (h, aux + a1 + a2), None
        if cfg.remat == "full":
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        (x, aux), _ = maybe_scan(
            group_body, (x, jnp.zeros((), jnp.float32)), params["groups"],
            cfg.unroll_layers)
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["norm_f"], x)
    logits = L.unembed(params["head"], x)
    if dist is not None:
        logits = dist.shard_logits(logits)
    return logits, aux


# ---------------------------------------------------------------------------
# Decode (single new token over a seq_len cache)
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ArchConfig, batch, seq, dtype=jnp.bfloat16):
    """Fixed-shape per-layer caches, stacked on the layer dim for scanning."""
    hd = cfg.hd
    fam = cfg.family

    eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq

    def kv(n):
        return {"k": jnp.zeros((n, batch, eff, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n, batch, eff, cfg.n_kv_heads, hd), dtype),
                "pos": jnp.broadcast_to(
                    jnp.arange(eff, dtype=jnp.int32), (n, eff))}

    def ssm_state(n):
        one = S.ssm_state_init(
            jax.tree_util.tree_map(lambda a: a[0], params_layers_ssm), batch,
            cfg.d_model, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if fam == "dense":
        return {"kv": kv(cfg.n_layers)}
    if fam == "moe":
        return {"kv": kv(cfg.n_layers)}
    if fam == "ssm":
        params_layers_ssm = params["layers"]["ssm"]
        return {"ssm": ssm_state(cfg.n_layers)}
    if fam == "hybrid":
        params_layers_ssm = params["layers"]["ssm"]
        return {"kv": kv(cfg.n_layers), "ssm": ssm_state(cfg.n_layers)}
    if fam == "encdec":
        enc_len = cfg.n_frontend_tokens
        return {"kv": kv(cfg.n_layers),
                "xk": jnp.zeros((cfg.n_layers, batch, enc_len,
                                 cfg.n_kv_heads, hd), dtype),
                "xv": jnp.zeros((cfg.n_layers, batch, enc_len,
                                 cfg.n_kv_heads, hd), dtype)}
    if fam == "vlm":
        k = cfg.cross_attn_interval
        n_groups = cfg.n_layers // k
        img = cfg.n_frontend_tokens
        return {"kv_self": kv(n_groups * (k - 1)),
                "xk": jnp.zeros((n_groups, batch, img, cfg.n_kv_heads, hd),
                                dtype),
                "xv": jnp.zeros((n_groups, batch, img, cfg.n_kv_heads, hd),
                                dtype)}
    raise ValueError(fam)


def decode_step(params, cfg: ArchConfig, token, cache, pos, *, dist=None):
    """token (B,1) int32; pos (B,1) int32 current position; returns
    (logits (B,1,V), new cache)."""
    fam = cfg.family
    x = L.embed(params["embed"], token)
    if dist is not None:
        x = dist.shard_activations(x)

    def attn_dec(lp, h, c, window=0):
        hn = L.rmsnorm(lp["ln1"], h)
        att, c = A.mha_decode(lp["attn"], hn, c, pos, cfg.n_heads,
                              cfg.n_kv_heads, cfg.hd, window=window,
                              rope_theta=cfg.rope_theta, dist=dist)
        return att, c

    if fam in ("dense", "moe"):
        def body(h, xs):
            lp, c = xs
            att, c = attn_dec(lp, h, c, cfg.sliding_window)
            h = h + att
            hn = L.rmsnorm(lp["ln2"], h)
            if fam == "moe":
                f, _ = moe(lp["moe"], hn, top_k=cfg.top_k,
                           group=cfg.moe_group, dist=dist)
            else:
                f = L.ffn(lp["ffn"], hn)
            return h + f, c
        x, kv = maybe_scan(body, x, (params["layers"], cache["kv"]),
                           cfg.unroll_layers)
        cache = {"kv": kv}
    elif fam == "ssm":
        def body(h, xs):
            lp, st = xs
            out, st = S.ssm_decode(lp["ssm"], L.rmsnorm(lp["ln1"], h), st,
                                   dist=dist)
            return h + out, st
        x, st = maybe_scan(body, x, (params["layers"], cache["ssm"]),
                           cfg.unroll_layers)
        cache = {"ssm": st}
    elif fam == "hybrid":
        def body(h, xs):
            lp, c, st = xs
            hn = L.rmsnorm(lp["ln1"], h)
            att, c = A.mha_decode(lp["attn"], hn, c, pos, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.hd,
                                  window=cfg.sliding_window,
                                  rope_theta=cfg.rope_theta, dist=dist)
            sm, st = S.ssm_decode(lp["ssm"], hn, st, dist=dist)
            h = h + (att + sm) * 0.5
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return h, (c, st)
        x, (kv, st) = maybe_scan(
            body, x, (params["layers"], cache["kv"], cache["ssm"]),
            cfg.unroll_layers)
        cache = {"kv": kv, "ssm": st}
    elif fam == "encdec":
        def body(h, xs):
            lp, c, xk, xv = xs
            att, c = attn_dec(lp, h, c)
            h = h + att
            hn = L.rmsnorm(lp["lnx"], h)
            B = hn.shape[0]
            q = L.linear(lp["xattn"]["wq"], hn).reshape(
                B, 1, cfg.n_heads, cfg.hd)
            enc_pos = jnp.arange(xk.shape[1], dtype=jnp.int32)
            o = A.attend_cached(A._grouped(q, cfg.n_kv_heads), xk, xv,
                                jnp.full((1,), 1 << 30, jnp.int32), enc_pos)
            h = h + L.linear(lp["xattn"]["wo"],
                             o.reshape(B, 1, cfg.n_heads * cfg.hd))
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return h, c
        x, kv = maybe_scan(
            body, x, (params["dec"], cache["kv"], cache["xk"],
                      cache["xv"]), cfg.unroll_layers)
        cache = dict(cache, kv=kv)
    elif fam == "vlm":
        k = cfg.cross_attn_interval
        n_groups = cfg.n_layers // k
        selfs = params["groups"]["selfs"]   # already (n_groups, k-1, ...)
        kv_self = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, k - 1, *a.shape[1:]),
            cache["kv_self"])

        def group_body(h, xs):
            gp_selfs, gp_cross, c_self, xk, xv = xs

            def self_body(hh, ys):
                lp, c = ys
                att, c = attn_dec(lp, hh, c)
                hh = hh + att
                hh = hh + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], hh))
                return hh, c
            h, c_self = maybe_scan(self_body, h, (gp_selfs, c_self),
                                   cfg.unroll_layers)
            hn = L.rmsnorm(gp_cross["ln1"], h)
            B = hn.shape[0]
            q = L.linear(gp_cross["xattn"]["wq"], hn).reshape(
                B, 1, cfg.n_heads, cfg.hd)
            img_pos = jnp.arange(xk.shape[1], dtype=jnp.int32)
            o = A.attend_cached(A._grouped(q, cfg.n_kv_heads), xk, xv,
                                jnp.full((1,), 1 << 30, jnp.int32), img_pos)
            h = h + jnp.tanh(gp_cross["gate"]).astype(h.dtype) * L.linear(
                gp_cross["xattn"]["wo"],
                o.reshape(B, 1, cfg.n_heads * cfg.hd))
            h = h + L.ffn(gp_cross["ffn"], L.rmsnorm(gp_cross["ln2"], h))
            return h, c_self
        x, kv_self = maybe_scan(
            group_body, x,
            (selfs, params["groups"]["cross"], kv_self, cache["xk"],
             cache["xv"]), cfg.unroll_layers)
        cache = dict(cache, kv_self=jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups * (k - 1), *a.shape[2:]), kv_self))
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["norm_f"], x)
    logits = L.unembed(params["head"], x)
    if dist is not None:
        logits = dist.shard_logits(logits)
    return logits, cache


def decode_step_ragged(params, cfg: ArchConfig, token, cache, pos, cap, *,
                       dist=None):
    """Continuous-batching decode step: ONE forward over every cache slot.

    token (B, 1) int32 per-slot current tokens; pos (B, 1) int32 per-slot
    positions; cap (B,) int32 per-slot ring capacities (free slots run as
    pos=0/cap=1 padding work whose outputs the engine discards).  cache is
    the ``serve.kvcache`` slot cache.  Returns (logits (B, 1, V), cache).

    Per slot the math is bit-identical to ``decode_step`` at B=1: the
    ragged attention masks by per-entry positions, every other op is
    row-wise, and MoE dispatches with ``group=1`` so batch occupancy can
    never change a token's expert-capacity outcome (at B=1 the group
    clamp makes ``group`` irrelevant, so this matches ``generate``
    exactly).  One compiled executable serves the engine's whole lifetime
    — admission/eviction only rewrite cache rows, never shapes.
    """
    fam = cfg.family
    x = L.embed(params["embed"], token)
    if dist is not None:
        x = dist.shard_activations(x)

    def attn_dec(lp, hn, c):
        return A.mha_decode_ragged(lp["attn"], hn, c, pos, cap, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd,
                                   window=cfg.sliding_window,
                                   rope_theta=cfg.rope_theta, dist=dist)

    if fam in ("dense", "moe"):
        def body(h, xs):
            lp, c = xs
            att, c = attn_dec(lp, L.rmsnorm(lp["ln1"], h), c)
            h = h + att
            hn = L.rmsnorm(lp["ln2"], h)
            if fam == "moe":
                f, _ = moe(lp["moe"], hn, top_k=cfg.top_k, group=1,
                           dist=dist)
            else:
                f = L.ffn(lp["ffn"], hn)
            return h + f, c
        x, kv = maybe_scan(body, x, (params["layers"], cache["kv"]),
                           cfg.unroll_layers)
        cache = {"kv": kv}
    elif fam == "ssm":
        def body(h, xs):
            lp, st = xs
            out, st = S.ssm_decode(lp["ssm"], L.rmsnorm(lp["ln1"], h), st,
                                   dist=dist)
            return h + out, st
        x, st = maybe_scan(body, x, (params["layers"], cache["ssm"]),
                           cfg.unroll_layers)
        cache = {"ssm": st}
    elif fam == "hybrid":
        def body(h, xs):
            lp, c, st = xs
            hn = L.rmsnorm(lp["ln1"], h)
            att, c = attn_dec(lp, hn, c)
            sm, st = S.ssm_decode(lp["ssm"], hn, st, dist=dist)
            h = h + (att + sm) * 0.5
            h = h + L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], h))
            return h, (c, st)
        x, (kv, st) = maybe_scan(
            body, x, (params["layers"], cache["kv"], cache["ssm"]),
            cfg.unroll_layers)
        cache = {"kv": kv, "ssm": st}
    else:
        raise NotImplementedError(
            f"family {fam!r} is not served by the continuous-batching "
            "engine (dense/moe/ssm/hybrid only)")

    x = L.rmsnorm(params["norm_f"], x)
    logits = L.unembed(params["head"], x)
    if dist is not None:
        logits = dist.shard_logits(logits)
    return logits, cache


# ---------------------------------------------------------------------------
# Fused decode loop (scan over decode_step — no per-token Python round-trip)
# ---------------------------------------------------------------------------

def decode_loop(params, cfg: ArchConfig, tok, cache, start_pos, n_new, *,
                temperature=0.0, key=None, dist=None):
    """Generate ``n_new`` tokens with ONE compiled program: a ``lax.scan``
    whose body is ``decode_step`` + sampling.  The per-token Python loop
    (dispatch + device sync every token) disappears; the whole decode is a
    single XLA while-loop on device.

    tok (B, 1) int32 first token to emit; start_pos (B, 1) int32 its
    position.  Returns (tokens (B, n_new), final cache) — tok itself is the
    first output token, matching the eager loop in
    ``serve.engine.generate_python``.
    """
    if key is None:
        key = jax.random.PRNGKey(0)

    def body(carry, i):
        tok, cache = carry
        pos = start_pos + i
        logits, cache = decode_step(params, cfg, tok, cache, pos, dist=dist)
        if temperature > 0:
            sub = jax.random.fold_in(key, i)
            nxt = jax.random.categorical(
                sub, logits[:, -1, :] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return (nxt.astype(jnp.int32), cache), tok

    (_, cache), toks = jax.lax.scan(
        body, (tok, cache), jnp.arange(n_new, dtype=jnp.int32))
    return jnp.swapaxes(toks[..., 0], 0, 1), cache      # (B, n_new)
