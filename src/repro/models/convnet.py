"""Small VGG/MobileNet-style conv nets for the paper-faithful CONV-layer
experiments (Fig 5/7, Table 2/4 reproductions run on these + synthetic
CIFAR-like data).  Weight layout: (out_ch, in_ch, kh, kw) = the paper's
(P, Q, Kh, Kw), so block-punched / pattern masks apply directly.

Sparse serving: ``serve.compile.compile_model`` installs a layout next to
each pruned conv (``params[name]["packed"]``): a ``core.packed.
PackedLayout`` of the im2col-lowered weight for block-punched layers, or a
``core.packed.TapLayout`` of per-filter tap lists for pattern/connectivity
layers.  ``convnet_apply`` dispatches on the layout type — block layouts
run through ``kernels.ops.sparse_conv2d`` (one BCS GEMM over extracted
patches), tap layouts through ``kernels.ops.sparse_conv2d_pattern`` (the
tap-gather kernel) — bias + relu fused in the kernel epilogue either way,
instead of the masked-dense ``lax.conv`` (kept below as the parity
oracle).  Depthwise layers are never packed (§5.2.4) and always take the
dense path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import module as M

# (name, out_ch, kh, kw, stride, depthwise)
VGG_TINY = [
    ("c1", 32, 3, 3, 1, False),
    ("c2", 64, 3, 3, 2, False),
    ("c3", 64, 3, 3, 1, False),
    ("c4", 128, 3, 3, 2, False),
    ("c5", 128, 1, 1, 1, False),
    ("c6", 128, 3, 3, 1, False),
]

MOBILE_TINY = [
    ("c1", 32, 3, 3, 1, False),
    ("dw2", 32, 3, 3, 1, True),
    ("pw2", 64, 1, 1, 1, False),
    ("dw3", 64, 3, 3, 2, True),
    ("pw3", 128, 1, 1, 1, False),
    ("c4", 128, 5, 5, 1, False),   # a non-3x3 kernel, per the paper's point
]


def convnet_init(key, arch=VGG_TINY, in_ch=3, n_classes=10,
                 dtype=jnp.float32):
    params = {}
    c = in_ch
    names = [a[0] for a in arch] + ["fc"]
    ks = M.split_keys(key, names)
    for (name, out, kh, kw, stride, dw) in arch:
        if dw:
            w = M.dense_init(ks[name], (c, 1, kh, kw), dtype,
                             scale=(kh * kw) ** -0.5)
        else:
            w = M.dense_init(ks[name], (out, c, kh, kw), dtype,
                             scale=(c * kh * kw) ** -0.5)
            c = out
        params[name] = {"w": w, "b": jnp.zeros((c,), dtype)}
    params["fc"] = {"w": M.dense_init(ks["fc"], (c, n_classes), dtype),
                    "b": jnp.zeros((n_classes,), dtype)}
    return params


def convnet_apply(params, x, arch=VGG_TINY, masks=None, implicit=None):
    """x: (B, H, W, Cin) -> logits (B, n_classes).

    ``implicit`` routes packed conv layers through the implicit-GEMM
    kernels (None = per-layer auto-selection by patch-tensor size, True /
    False force one mode — see ``kernels.ops.sparse_conv2d``)."""
    from repro.core.packed import DegradedLayer
    m = masks or {}
    for (name, out, kh, kw, stride, dw) in arch:
        packed = params[name].get("packed")
        if isinstance(packed, DegradedLayer):
            packed = None                # validated-corrupt: masked-dense
        if packed is not None and not dw:
            from repro.kernels import ops  # late import: kernels -> core only
            from repro.core.packed import TapLayout
            conv = (ops.sparse_conv2d_pattern
                    if isinstance(packed, TapLayout) else ops.sparse_conv2d)
            x = conv(x, packed, kh=kh, kw=kw, stride=stride,
                     bias=params[name]["b"], act="relu", implicit=implicit)
            continue
        w = params[name]["w"]
        mk = m.get(name)
        if mk is not None:
            w = w * mk.astype(w.dtype)
        if dw:
            # (C,1,kh,kw) -> depthwise
            kernel = w.transpose(2, 3, 1, 0)      # (kh,kw,1,C)
            y = jax.lax.conv_general_dilated(
                x, kernel, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=x.shape[-1])
        else:
            kernel = w.transpose(2, 3, 1, 0)      # (kh,kw,Cin,Cout)
            y = jax.lax.conv_general_dilated(
                x, kernel, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(y + params[name]["b"])
    x = jnp.mean(x, axis=(1, 2))                  # global average pool
    return x @ params["fc"]["w"] + params["fc"]["b"]


def synthetic_images(key, batch, n_classes=10, size=16, hard=False):
    """CIFAR-like synthetic classification (position-INVARIANT class
    signals — the readout is global-average-pooled).

    ``hard=False`` (the paper's 'easy dataset' regime): the class sets a
    distinct 3-channel color mixture — nearly linear in channel means,
    solvable to high accuracy by any over-parameterized net.
    ``hard=True``: channel means are identical across classes; the class
    only sets the spatial texture FREQUENCY — needs real (conv) feature
    extraction, so pruning damage shows (the 'hard dataset' regime)."""
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    yy, xx = jnp.mgrid[0:size, 0:size].astype(jnp.float32) / size
    if hard:
        freq = 1.0 + labels.astype(jnp.float32) * 0.5      # 1.0 .. 5.5
        tex = jnp.sin(2 * jnp.pi * freq[:, None, None] * xx[None]) * \
            jnp.sin(2 * jnp.pi * freq[:, None, None] * yy[None])
        img = jnp.repeat(tex[..., None], 3, axis=-1)
        noise = jax.random.normal(k2, img.shape) * 0.3
        img = img + noise
    else:
        angles = labels.astype(jnp.float32) / n_classes * 2 * jnp.pi
        mix = jnp.stack([jnp.cos(angles), jnp.sin(angles),
                         jnp.cos(2 * angles)], axis=-1)     # (B, 3)
        smooth = 0.5 + 0.5 * jnp.sin(2 * jnp.pi * (xx + yy))[None]
        img = mix[:, None, None, :] * smooth[..., None]
        noise = jax.random.normal(k2, img.shape) * 0.3
        img = img + noise
    return img.astype(jnp.float32), labels


def classify_loss(params, batch, arch=VGG_TINY, masks=None):
    logits = convnet_apply(params, batch[0], arch, masks)
    labels = batch[1]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def accuracy(params, batch, arch=VGG_TINY, masks=None):
    logits = convnet_apply(params, batch[0], arch, masks)
    return jnp.mean((jnp.argmax(logits, -1) == batch[1]).astype(jnp.float32))
