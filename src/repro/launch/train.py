"""End-to-end training driver: mesh -> sharded init -> (optional pruning
schedule) -> train loop with checkpoint/restart, straggler monitoring, and
deterministic data shards.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 50 --prune --target-rate 0.6
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.core import reweighted as RW
from repro.core.mapper_rule import lm_layers, map_rules
from repro.data.pipeline import synthetic_batch
from repro.distributed import checkpoint as CKPT
from repro.distributed import sharding as SH
from repro.distributed.elastic import StragglerMonitor, rebuild_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--prune", action="store_true")
    ap.add_argument("--target-rate", type=float, default=0.6)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    mesh = make_local_mesh() if args.model_parallel == 1 else \
        rebuild_mesh(model_parallel=args.model_parallel)
    dist = SH.make_dist(mesh, cfg, args.batch)

    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    p_shard = SH.param_shardings(params, cfg, mesh)
    params = jax.device_put(params, p_shard)

    reweighted = None
    masks, alphas = None, None
    spec = None
    if args.prune:
        layers = lm_layers(cfg, tokens=args.batch * args.seq)
        spec, report = map_rules(layers, dataset_hard=False,
                                 compression=1 / (1 - args.target_rate))
        # snap blocks to the (possibly smoke-sized) layer dims
        spec = [(p, RW.SchemeChoice(c.scheme, (
            min(c.block[0], 8), min(c.block[1], 16))) if c.scheme != "none"
            else c) for p, c in spec]
        reweighted = RW.ReweightedConfig(spec=tuple(spec), lam=1e-3)
        alphas = RW.init_alphas(params, spec)

    opt_init, train_step = make_train_step(cfg, dist=dist, lr=args.lr,
                                           reweighted=reweighted)
    opt_state = opt_init(params)
    train_step = jax.jit(train_step)

    start = 0
    if args.resume:
        restored, step0 = CKPT.restore(args.ckpt_dir,
                                       {"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = step0
            print(f"resumed from step {start}")

    mon = StragglerMonitor()
    prune_at = int(args.steps * 0.6) if args.prune else None
    for step in range(start, args.steps):
        if reweighted and step and step % reweighted.reweight_every == 0 \
                and (prune_at is None or step < prune_at):
            alphas = RW.update_alphas(params, reweighted)
        if prune_at is not None and step == prune_at:
            tau = RW.global_threshold(params, spec, args.target_rate)
            masks = RW.masks_for_spec(params, spec, threshold=tau)
            alphas = None
            rep = RW.sparsity_report(params, masks)["__overall__"]
            print(f"step {step}: pruned -> density {rep['density']:.3f} "
                  f"(compression {rep['compression']:.2f}x)")
        batch = synthetic_batch(
            0, step, args.batch, args.seq, cfg.vocab,
            frontend_tokens=cfg.n_frontend_tokens
            if cfg.family in ("encdec", "vlm") else 0, d_model=cfg.d_model)
        t0 = time.time()
        params, opt_state, metrics = train_step(params, opt_state, batch,
                                                masks, alphas)
        dt = time.time() - t0
        if mon.observe(dt):
            print(f"step {step}: straggler detected ({dt:.2f}s) — backup "
                  f"shard recompute would trigger here")
        if step % 10 == 0:
            print(f"step {step}: loss {float(metrics['loss']):.4f} "
                  f"({dt*1e3:.0f} ms)")
        if step and step % args.ckpt_every == 0:
            CKPT.save(args.ckpt_dir, step,
                      {"params": params, "opt": opt_state})
    print(f"final loss {float(metrics['loss']):.4f}")
    return params, masks


if __name__ == "__main__":
    main()
