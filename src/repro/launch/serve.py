"""Serving driver: batched prefill + fused-scan decode with an optionally
pruned-and-compiled model — the whole §4.3 pipeline from the CLI:
map schemes -> one-shot masks -> compile_model (BCS packing) -> generate.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke --sparse
"""
from __future__ import annotations

import argparse
import logging
import time

import jax

from repro import configs
from repro.core import reweighted as RW
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T
from repro.serve.compile import compile_model, compiled_summary
from repro.serve.engine import generate
from repro.train.trainer import apply_masks

SPARSE_SPEC = [(r"(attn/w[qkvo]|(ffn|moe)/(gate|up|down))/w",
                RW.SchemeChoice("block", (16, 16))),
               # SSM in/out projections pack too (PR 3); the narrower (16, 8)
               # block tiles the smoke mamba2 in_proj (proj dim 296 = 37*8)
               (r"ssm/(in_proj|out_proj)/w",
                RW.SchemeChoice("block", (16, 8)))]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sparse", action="store_true",
                    help="block-prune, compile to BCS, serve on the "
                         "Pallas sparse kernel")
    ap.add_argument("--prune-rate", type=float, default=0.6)
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="AOT artifact store: load the packed layouts "
                         "from DIR when the model digest matches "
                         "(checksum-verified + validated), else pack "
                         "fresh and publish — kills the cold start on "
                         "replica restart")
    args = ap.parse_args(argv)

    if args.artifacts:
        # surface the store's structured warm-start / fallback reasons
        logging.basicConfig(level=logging.INFO)
    cfg = configs.get(args.arch, smoke=args.smoke)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, args.batch, args.prompt_len, cfg.vocab,
                        frontend_tokens=cfg.n_frontend_tokens
                        if cfg.family in ("encdec", "vlm") else 0,
                        d_model=cfg.d_model)
    if args.sparse:
        masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None,
                                         rate=args.prune_rate)
        params = apply_masks(params, masks)
        t0 = time.time()
        params, report = compile_model(params, masks, SPARSE_SPEC,
                                       keep_dense=False,
                                       artifact_dir=args.artifacts)
        dt_compile = time.time() - t0
        print(f"compile_model in {dt_compile:.2f}s"
              + (f" (artifact store: {args.artifacts})"
                 if args.artifacts else "") + ":")
        print(compiled_summary(report))

    t0 = time.time()
    out = jax.block_until_ready(
        generate(params, cfg, b["tokens"], args.new_tokens,
                 frontend=b.get("frontend")))
    dt = time.time() - t0
    mode = "sparse" if args.sparse else "dense"
    print(f"{args.arch} [{mode}]: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
