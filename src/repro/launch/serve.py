"""Serving driver: batched prefill + decode with a (optionally pruned)
model; demonstrates the BCS/Pallas path on a single projection.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import synthetic_batch
from repro.models import transformer as T
from repro.serve.engine import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, args.batch, args.prompt_len, cfg.vocab,
                        frontend_tokens=cfg.n_frontend_tokens
                        if cfg.family in ("encdec", "vlm") else 0,
                        d_model=cfg.d_model)
    t0 = time.time()
    out = generate(params, cfg, b["tokens"], args.new_tokens,
                   frontend=b.get("frontend"))
    dt = time.time() - t0
    print(f"{args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
