"""Serving driver: batched prefill + fused-scan decode with an optionally
pruned-and-compiled model — the whole §4.3 pipeline from the CLI:
map schemes -> one-shot masks -> compile_model (BCS packing) -> generate.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke --sparse

With ``--batch-size``/``--arrival-rate`` the continuous-batching engine
replaces the one-shot ``generate`` call: a simulated open-loop workload
(requests arriving at a fixed rate, mixed prompt lengths) streams through
``serve.engine.ServingEngine``, with a periodic log line reporting batch
occupancy, admitted/evicted counts, and the pack-cache counters:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \\
      --sparse --batch-size 8 --arrival-rate 1.5 --requests 24
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro import configs
from repro.core import reweighted as RW
from repro.data.pipeline import synthetic_batch
from repro.kernels.ops import pack_cache_stats
from repro.models import transformer as T
from repro.serve.compile import CompileSpec, compile_model, compiled_summary
from repro.serve.engine import ServingEngine, generate
from repro.train.trainer import apply_masks

SPARSE_SPEC = [(r"(attn/w[qkvo]|(ffn|moe)/(gate|up|down))/w",
                RW.SchemeChoice("block", (16, 16))),
               # SSM in/out projections pack too (PR 3); the narrower (16, 8)
               # block tiles the smoke mamba2 in_proj (proj dim 296 = 37*8)
               (r"ssm/(in_proj|out_proj)/w",
                RW.SchemeChoice("block", (16, 8)))]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sparse", action="store_true",
                    help="block-prune, compile to BCS, serve on the "
                         "Pallas sparse kernel")
    ap.add_argument("--prune-rate", type=float, default=0.6)
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="AOT artifact store: load the packed layouts "
                         "from DIR when the model digest matches "
                         "(checksum-verified + validated), else pack "
                         "fresh and publish — kills the cold start on "
                         "replica restart")
    ap.add_argument("--batch-size", type=int, default=0, metavar="SLOTS",
                    help="continuous-batching engine slot count; > 0 "
                         "switches from one-shot generate to the "
                         "ServingEngine open-loop workload")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="simulated open-loop arrivals per decode step "
                         "(default: saturate — everything arrives at "
                         "step 0); implies the engine path")
    ap.add_argument("--requests", type=int, default=16,
                    help="engine path: number of simulated requests")
    ap.add_argument("--seq-cap", type=int, default=128,
                    help="engine path: per-slot KV ring capacity")
    ap.add_argument("--log-every", type=int, default=8,
                    help="engine path: steps between periodic log lines")
    args = ap.parse_args(argv)

    if args.artifacts:
        # surface the store's structured warm-start / fallback reasons
        logging.basicConfig(level=logging.INFO)
    cfg = configs.get(args.arch, smoke=args.smoke)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, args.batch, args.prompt_len, cfg.vocab,
                        frontend_tokens=cfg.n_frontend_tokens
                        if cfg.family in ("encdec", "vlm") else 0,
                        d_model=cfg.d_model)
    if args.sparse:
        masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None,
                                         rate=args.prune_rate)
        params = apply_masks(params, masks)
        t0 = time.time()
        params, report = compile_model(params, masks, SPARSE_SPEC,
                                       spec=CompileSpec(keep_dense=False),
                                       artifact_dir=args.artifacts)
        dt_compile = time.time() - t0
        print(f"compile_model in {dt_compile:.2f}s"
              + (f" (artifact store: {args.artifacts})"
                 if args.artifacts else "") + ":")
        print(compiled_summary(report))

    mode = "sparse" if args.sparse else "dense"
    if args.batch_size or args.arrival_rate:
        _run_engine(params, cfg, args, mode)
        return

    t0 = time.time()
    out = jax.block_until_ready(
        generate(params, cfg, b["tokens"], args.new_tokens,
                 frontend=b.get("frontend")))
    dt = time.time() - t0
    print(f"{args.arch} [{mode}]: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())


def _run_engine(params, cfg, args, mode):
    """Simulated open-loop serving: ``--requests`` prompts of mixed lengths
    arrive at ``--arrival-rate`` per step and stream through the
    continuous-batching engine; the periodic log line surfaces the
    observability counters (occupancy, admitted/evicted, pack cache)."""
    n_slots = args.batch_size or 8
    eng = ServingEngine(params, cfg, n_slots=n_slots, seq_cap=args.seq_cap)
    rng = np.random.RandomState(0)
    rate = args.arrival_rate
    # mixed prompt-length buckets exercise the per-bucket prefill cache
    lengths = (args.prompt_len, max(2, args.prompt_len // 2),
               max(2, 3 * args.prompt_len // 4))
    for i in range(args.requests):
        prompt = rng.randint(1, cfg.vocab,
                             size=lengths[i % len(lengths)]).tolist()
        eng.submit(prompt, args.new_tokens,
                   arrival=int(i / rate) if rate else 0)

    t0 = time.time()
    while eng.sched.has_work():
        eng.step()
        if eng.stats["steps"] % args.log_every == 0:
            s, pc = eng.stats, pack_cache_stats()
            print(f"step {s['steps']:>4}: occupancy "
                  f"{eng.mean_occupancy():.2f} admitted {s['admitted']} "
                  f"evicted {s['evicted']} queued {eng.sched.queued()} "
                  f"tokens {s['tokens']} | pack cache hits {pc['hits']} "
                  f"misses {pc['misses']} evictions {pc['evictions']}")
    dt = time.time() - t0
    s = eng.stats
    print(f"{args.arch} [{mode}, engine B={n_slots}"
          + (f", rate={rate}/step" if rate else ", saturated")
          + f"]: {s['finished']}/{args.requests} requests, {s['tokens']} "
          f"tokens in {dt:.2f}s ({s['tokens'] / dt:.1f} tok/s incl. "
          f"prefills), mean occupancy {eng.mean_occupancy():.2f}")


if __name__ == "__main__":
    main()
