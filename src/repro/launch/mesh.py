"""Production mesh factory.  A FUNCTION (never a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic reshapes, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(tp: int = 1):
    """Local mesh with the production axis names — lets smoke tests run
    the exact sharded code path on CPU.  ``tp`` > 1 puts that many local
    devices on the "model" axis (pair with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake them);
    the default stays the historical 1-device (1, 1) mesh."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp > jax.device_count():
        raise ValueError(
            f"tp={tp} needs more devices than the {jax.device_count()} "
            "available (set --xla_force_host_platform_device_count)")
    return jax.make_mesh((1, tp), ("data", "model"))
