"""Generate the §Roofline table from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.roofline_report
writes experiments/roofline.md + roofline.json and prints the table."""
from __future__ import annotations

import json
import pathlib

from repro.core import roofline as RL

BASE = pathlib.Path(__file__).resolve().parents[3] / "experiments"


def main():
    rows = RL.load_and_analyze(BASE / "dryrun")
    md = RL.to_markdown(rows)
    (BASE / "roofline.md").write_text(md)
    (BASE / "roofline.json").write_text(json.dumps(rows, indent=1,
                                                   default=float))
    print(md)
    ok = [r for r in rows if r.get("status") == "OK"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["t_collective_s"] /
                   max(r["step_time_bound_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} × "
              f"{worst['shape']} ({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:  {coll['arch']} × {coll['shape']}")


if __name__ == "__main__":
    main()
