import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first init.  512 placeholder host devices let
# jax.make_mesh build the production meshes (16x16 single-pod, 2x16x16
# multi-pod) for compile-only dry-runs.  Never set this globally — smoke
# tests and benches must see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
cell with ShapeDtypeStruct inputs (zero allocation), record
memory_analysis / cost_analysis / per-collective byte counts to JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import pathlib
import re
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train.trainer import make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from partitioned HLO.  Accounting:
    all-reduce counts 2x operand (reduce-scatter + all-gather phases);
    all-gather / all-to-all count result bytes; reduce-scatter and
    collective-permute count operand bytes."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f" {c}-start(" in line:
                op = c
                break
        if op is None:
            continue
        # optimized HLO references operands by NAME only; the (possibly
        # tuple) RESULT shape(s) on the lhs of the op name carry the sizes.
        lhs = line.split(f" {op}", 1)[0]
        shapes = _SHAPE_RE.findall(lhs)
        if not shapes:
            continue
        result_b = sum(_shape_bytes(d, s) for d, s in shapes)
        # per-device wire traffic: all-reduce ~ 2x payload (RS+AG phases);
        # all-gather/all-to-all/permute ~ result bytes; reduce-scatter's
        # result is the scattered shard (documented underestimate).
        out[op] += 2 * result_b if op == "all-reduce" else result_b
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def _batch_specs(cfg, shape_id, mesh, dist):
    b = dist.batch_axes if dist.batch_axes else None
    mdl = "model"
    kind = SHAPES[shape_id]["kind"]
    if kind in ("train", "prefill"):
        specs = {"tokens": P(b, None)}
        if kind == "train":
            specs["labels"] = P(b, None)
        if cfg.family in ("encdec", "vlm"):
            specs["frontend"] = P(b, None, None)
        return specs
    # decode: token/pos + cache
    cache_abs = configs.input_specs(cfg, shape_id)["cache"]

    def cache_spec(path, leaf):
        s = SH.M.path_str(path)
        if leaf.ndim == 5:            # (L, B, S, KV, hd) or ssm h (L,B,H,P,N)
            if "/h" in s or s.endswith("h"):
                return P(None, b, mdl, None, None)
            return P(None, b, mdl, None, None)
        if leaf.ndim == 4:            # conv state (L, B, W-1, C)
            return P(None, b, None, mdl)
        if leaf.ndim == 2:            # pos (L, S)
            return P(None, None)
        return P(*([None] * leaf.ndim))

    cache_specs = jax.tree_util.tree_map_with_path(cache_spec, cache_abs)
    return {"token": P(b, None), "pos": P(b, None), "cache": cache_specs}


def build_step(cfg, shape_id, mesh):
    """Returns (fn, arg_specs(ShapeDtypeStructs), in_shardings)."""
    sh = SHAPES[shape_id]
    # train AND prefill shard FSDP/ZeRO-3 (1M tokens: activations >>
    # weights, §Perf granite iter 1); decode lowers with TP (weights
    # stationary, one token).  Disaggregated serving re-shards the cache
    # between the prefill and decode pools.
    mode = cfg.train_shard_mode if sh["kind"] in ("train", "prefill") \
        else "tp"
    dist = SH.make_dist(mesh, cfg, sh["batch"], mode=mode)
    params_abs = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    p_specs = SH.param_specs(params_abs, cfg, mesh, mode=mode)
    p_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), p_specs)
    ispecs = configs.input_specs(cfg, shape_id)
    b_specs = _batch_specs(cfg, shape_id, mesh, dist)
    b_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), b_specs)
    kind = sh["kind"]

    if kind == "train":
        opt_init, train_step = make_train_step(cfg, dist=dist)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        o_specs = SH.opt_state_specs(opt_abs, p_specs, cfg.optimizer)
        o_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                         o_specs)

        def fn(params, opt_state, batch):
            return train_step(params, opt_state, batch)
        args = (params_abs, ispecs)  # placeholder, replaced below
        args = (params_abs, opt_abs, ispecs)
        rep = NamedSharding(mesh, P())
        out_shardings = (p_shard, o_shard,
                         {"loss": rep, "grad_norm": rep})
        shardings = (p_shard, o_shard, b_shard)
        donate = (0, 1)     # params + opt state update in place
        return fn, args, shardings, donate, out_shardings
    elif kind == "prefill":
        from repro.serve.engine import prefill

        def fn(params, batch):
            return prefill(params, cfg, batch["tokens"],
                           frontend=batch.get("frontend"), dist=dist)
        args = (params_abs, ispecs)
        shardings = (p_shard, b_shard)
        donate = ()
    else:  # decode

        def fn(params, batch):
            return T.decode_step(params, cfg, batch["token"], batch["cache"],
                                 batch["pos"], dist=dist)
        args = (params_abs, ispecs)
        shardings = (p_shard, b_shard)
        donate = (1,)       # KV/SSM cache updated in place
    return fn, args, shardings, donate, None


def _probe_cfgs(cfg):
    """Two reduced-depth UNROLLED configs (u1, u2 layer-units) for cost
    extrapolation: XLA cost analysis counts lax.scan bodies once, so the
    true per-step cost is  c(u1) + (units-1) * (c(u2) - c(u1))."""
    if cfg.family == "vlm":
        k = cfg.cross_attn_interval
        units = cfg.n_layers // k
        return (cfg.replace(n_layers=k, unroll_layers=True, remat="none"),
                cfg.replace(n_layers=2 * k, unroll_layers=True,
                            remat="none"), units)
    if cfg.family == "encdec":
        assert cfg.n_layers == cfg.n_enc_layers
        return (cfg.replace(n_layers=1, n_enc_layers=1, unroll_layers=True,
                            remat="none"),
                cfg.replace(n_layers=2, n_enc_layers=2, unroll_layers=True,
                            remat="none"), cfg.n_layers)
    return (cfg.replace(n_layers=1, unroll_layers=True, remat="none"),
            cfg.replace(n_layers=2, unroll_layers=True, remat="none"),
            cfg.n_layers)


def _compile_costs(cfg, shape_id, mesh):
    fn, args, shardings, donate, out_sh = build_step(cfg, shape_id, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
    flops = cost.get("flops", 0.0) if isinstance(cost, dict) else 0.0
    byt = cost.get("bytes accessed", 0.0) if isinstance(cost, dict) else 0.0
    return {"flops": flops, "bytes": byt, "coll": coll["total"],
            "coll_by_op": {k: coll[k] for k in _COLLECTIVES}}


def probe_extrapolated(cfg, shape_id, mesh) -> dict:
    """True per-device per-step cost via L=1/L=2 unrolled probes."""
    c1_cfg, c2_cfg, units = _probe_cfgs(cfg)
    c1 = _compile_costs(c1_cfg, shape_id, mesh)
    c2 = _compile_costs(c2_cfg, shape_id, mesh)
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = c1[k] + (units - 1) * (c2[k] - c1[k])
    out["coll_by_op"] = {
        k: c1["coll_by_op"][k]
        + (units - 1) * (c2["coll_by_op"][k] - c1["coll_by_op"][k])
        for k in _COLLECTIVES}
    out["units"] = units
    out["probe_l1"] = {k: c1[k] for k in ("flops", "bytes", "coll")}
    out["probe_l2"] = {k: c2[k] for k in ("flops", "bytes", "coll")}
    # remat correction: the probes run without remat; with remat="full" the
    # backward pass recomputes each layer forward (~ +1/3 of train flops)
    if SHAPES[shape_id]["kind"] == "train" and cfg.remat == "full":
        out["flops_remat"] = out["flops"] * 4.0 / 3.0
    return out


def run_cell(arch: str, shape_id: str, multi_pod: bool, save=True,
             verbose=True, probe=True) -> dict:
    cfg = configs.get(arch)
    ok, why = configs.cell_is_supported(cfg, shape_id)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name}
    if not ok:
        rec["status"] = why
        if save:
            _save(rec)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, shardings, donate, out_sh = build_step(cfg, shape_id, mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec.update({
        "status": "OK",
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if isinstance(cost, dict) and k in cost},
        "collectives": coll,
    })
    if not isinstance(cost, dict):
        rec["cost"] = {"raw": str(cost)[:500]}
    if probe and not multi_pod:
        # single-pod only: the roofline table reads these (§Roofline)
        try:
            rec["extrapolated"] = probe_extrapolated(cfg, shape_id, mesh)
        except Exception as e:  # noqa: BLE001
            rec["extrapolated"] = {"error": str(e)[:500]}
    if verbose:
        print(f"[{arch} × {shape_id} × {mesh_name}] OK "
              f"compile={t_compile:.1f}s flops={rec['cost'].get('flops')} "
              f"coll={coll['total']/1e9:.2f}GB "
              f"temp={rec['memory']['temp_bytes']}")
        print("  memory_analysis:", rec["memory"])
    if save:
        _save(rec)
    return rec


def _save(rec):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    p = OUT_DIR / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    p.write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    arch_ids = list(configs.ALIASES) if (args.all or not args.arch) \
        else [args.arch]
    shape_ids = list(SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in arch_ids:
        for shape_id in shape_ids:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                if args.skip_existing and (
                        OUT_DIR / f"{arch}__{shape_id}__{mesh_name}.json"
                        ).exists():
                    continue
                try:
                    run_cell(arch, shape_id, mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    print(f"[{arch} × {shape_id} × "
                          f"{'multi' if mp else 'single'}] FAIL: {e}")
                    failures.append((arch, shape_id, mp, str(e)[:2000]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
