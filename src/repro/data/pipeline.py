"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, shard) so ANY host can recompute
ANY shard — this is the straggler/elastic story: no data-loader state to
checkpoint, and a replacement host joining mid-run reproduces exactly the
shard it inherits (DESIGN.md §5).

The synthetic task is a noisy learned-bigram language: token_{t+1} =
perm[token_t] with prob (1-noise) else uniform.  Models drive loss well below
uniform entropy quickly, giving pruning experiments a real accuracy signal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bigram_perm(vocab, seed=7):
    return jax.random.permutation(jax.random.PRNGKey(seed), vocab)


def synthetic_batch(seed, step, batch, seq, vocab, noise=0.3, shard=0,
                    frontend_tokens=0, d_model=0):
    """Returns {'tokens': (B,S+? int32), 'labels': (B,S)} (+ 'frontend')."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    perm = bigram_perm(vocab)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)

    def gen(tok, ks):
        kk1, kk2 = ks
        nxt = perm[tok]
        rnd = jax.random.randint(kk1, tok.shape, 0, vocab)
        use_rnd = jax.random.uniform(kk2, tok.shape) < noise
        nxt = jnp.where(use_rnd, rnd, nxt)
        return nxt, nxt

    keys = jax.random.split(k2, 2 * seq).reshape(seq, 2, 2)
    _, toks = jax.lax.scan(gen, first[:, 0], (keys[:, 0], keys[:, 1]))
    toks = jnp.concatenate([first, toks.T], axis=1)      # (B, S+1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if frontend_tokens:
        out["frontend"] = jax.random.normal(
            k3, (batch, frontend_tokens, d_model), jnp.bfloat16)
    return out


def host_shard(global_batch, n_hosts, host_id):
    """Contiguous per-host slice of the global batch."""
    per = global_batch // n_hosts
    return host_id * per, per
