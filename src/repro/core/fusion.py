"""Layer-fusion decisions (paper §4.3 + Appendix A.1), TPU edition.

XLA already fuses elementwise chains; what a compiler-aware pruning
framework still owns on TPU:
  * QKV fusion: wq/wk/wv share the input activation — fusing them into one
    (D, (H+2KV)*hd) block-sparse GEMM reads x from HBM once.
  * gate/up fusion: same for the SwiGLU pair.
  * epilogue fusion: bias + activation + (de)quant folded into the Pallas
    kernel epilogue (kernels/bsr_matmul.py) instead of a second HBM pass.
Fusion legality for *pruned* layers: fused weights must share the pruning
block grid along the shared (input) dimension — enforced here."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.reweighted import match


@dataclass(frozen=True)
class FusionPlan:
    groups: tuple          # tuple of tuples of param paths fused together
    saved_hbm_reads: int   # activation bytes saved per application


def plan_fusions(cfg, tokens: int) -> FusionPlan:
    D = cfg.d_model
    groups = []
    if cfg.n_heads:
        groups.append(("attn/wq/w", "attn/wk/w", "attn/wv/w"))
    if cfg.d_ff:
        groups.append(("ffn/gate/w", "ffn/up/w"))
    saved = tokens * D * 2 * (len(groups))
    return FusionPlan(groups=tuple(groups), saved_hbm_reads=saved)


def fusion_legal(spec, paths) -> bool:
    """Fused members must share block row-granularity on the K dim."""
    choices = [match(spec, p) for p in paths]
    if any(c is None for c in choices):
        return False
    bks = {c.block[0] for c in choices if c.scheme.startswith("block")}
    return len(bks) <= 1


def fuse_weights(ws) -> jnp.ndarray:
    """Concatenate along the output dim: (K, N1+N2+...)."""
    return jnp.concatenate(ws, axis=-1)
