"""Structural validation of the packed interchange layouts.

Every invariant the sparse executors rely on is checked here, host-side,
before a ``core.packed.PackedLayout``/``TapLayout`` is allowed anywhere
near a kernel launch.  The normal pack path (``kernels.ops.pack`` /
``pack_taps``) produces layouts that satisfy all of them by construction —
this module exists for layouts that arrive from OUTSIDE the process: the
AOT artifact store (``serve.artifacts``) and checkpoint restores, where a
corrupted, truncated, or stale file could otherwise be consumed silently
and mis-execute (an out-of-range ``k_idx`` gathers the wrong weight block;
a broken ``inv_perm`` scrambles output columns).  A bad layout must raise
a structured ``LayoutError`` so the loader can log the reason and fall
back to a fresh pack — never serve wrong outputs.

Taxonomy (one subclass per failure class, ``code`` is the stable tag):

  ``LayoutStructureError``    bin tuples inconsistent, leaf shape/dtype or
                              stack-dim mismatches, missing leaves
  ``LayoutGeometryError``     block does not divide shape, bin sizes do
                              not tile the column axis, bad group size
  ``LayoutIndexError``        ``k_idx``/``t_idx``/``alive`` out of range
  ``LayoutCountError``        ``nnz`` exceeds its bin's padded degree (or
                              the physical maximum)
  ``LayoutPermutationError``  ``perm``/``inv_perm`` not mutually inverse
                              permutations (or only one present)
  ``LayoutAuxError``          ``conv_taps``/``k_full`` aux inconsistent
                              with the layout geometry
  ``LayoutQuantError``        quantized-value invariants broken: int
                              values without ``scales`` (or scales on
                              float values), bin-count/shape/dtype
                              mismatches, negative or non-finite scales
  ``LayoutNumericsError``     NaN/Inf in a float ``values`` bin — bit rot
                              in the packed weights themselves, which
                              every structural check above would pass

``validate_layout`` checks one layout; ``validate_tree`` walks an
exec-param tree and checks every ``"packed"`` entry
(``core.packed.DegradedLayer`` sentinels are skipped: they carry no
leaves and already record WHY their layout was retired).
"""
from __future__ import annotations

import numpy as np

from repro.core.packed import DegradedLayer, PackedLayout, TapLayout


class LayoutError(ValueError):
    """Base of the layout-invariant taxonomy.

    Carries the failure class (``code``), the offending ``field``, the
    degree ``bin`` when the failure is per-bin, and the layer ``path``
    when validated out of a tree — everything a loader needs to log a
    structured fallback reason.
    """

    code = "invalid"

    def __init__(self, detail, *, field=None, bin=None, path=None):
        self.detail = detail
        self.field = field
        self.bin = bin
        self.path = path
        where = field or "?"
        if bin is not None:
            where += f"[bin {bin}]"
        prefix = f"{path}: " if path else ""
        super().__init__(f"{prefix}[{self.code}] {where}: {detail}")


class LayoutStructureError(LayoutError):
    """Bin tuples / leaf shapes / stack dims are inconsistent."""

    code = "structure"


class LayoutGeometryError(LayoutError):
    """Block or group does not tile the declared dense shape."""

    code = "geometry"


class LayoutIndexError(LayoutError):
    """An index leaf points outside its addressable range."""

    code = "index_range"


class LayoutCountError(LayoutError):
    """``nnz`` exceeds its bin's padded degree or the physical max."""

    code = "count"


class LayoutPermutationError(LayoutError):
    """``perm``/``inv_perm`` are not mutually inverse permutations."""

    code = "permutation"


class LayoutAuxError(LayoutError):
    """Static aux (``conv_taps``/``k_full``) disagrees with geometry."""

    code = "aux"


class LayoutQuantError(LayoutError):
    """Quantized values and their ``scales`` leaves disagree: int values
    with no scales, scales on float values, wrong bin count / shape /
    dtype, or negative / non-finite scale entries."""

    code = "quant"


class LayoutNumericsError(LayoutError):
    """A float ``values`` bin carries NaN/Inf entries: structurally the
    layout is fine, but one decode step through it poisons every output
    column it touches — exactly the corruption the serving engine's
    quarantine would otherwise only catch AFTER garbage logits."""

    code = "non_finite"


def _as_host(x):
    """Leaf -> numpy without copying when already host-side."""
    return np.asarray(x)


def _check_perm_pair(perm, inv_perm, n, path):
    """perm/inv_perm: both absent, or mutually inverse permutations of
    ``range(n)`` on the trailing axis (leading stack dims allowed)."""
    if perm is None and inv_perm is None:
        return
    if perm is None or inv_perm is None:
        missing = "perm" if perm is None else "inv_perm"
        raise LayoutPermutationError(
            f"{missing} is None while its partner is present",
            field=missing, path=path)
    p = _as_host(perm)
    ip = _as_host(inv_perm)
    for name, a in (("perm", p), ("inv_perm", ip)):
        if a.shape[-1] != n:
            raise LayoutStructureError(
                f"trailing axis {a.shape[-1]} != {n} columns",
                field=name, path=path)
        if not np.issubdtype(a.dtype, np.integer):
            raise LayoutStructureError(
                f"dtype {a.dtype} is not integral", field=name, path=path)
    p2 = p.reshape(-1, n)
    ip2 = ip.reshape(-1, n)
    if p2.shape != ip2.shape:
        raise LayoutStructureError(
            f"perm stack dims {p.shape[:-1]} != inv_perm {ip.shape[:-1]}",
            field="inv_perm", path=path)
    ar = np.arange(n)
    if not (np.all(np.sort(p2, axis=1) == ar)
            and np.all(np.sort(ip2, axis=1) == ar)):
        raise LayoutPermutationError(
            f"not a permutation of range({n})", field="perm", path=path)
    if not np.all(np.take_along_axis(ip2, p2, axis=1) == ar):
        raise LayoutPermutationError(
            "inv_perm[perm] != identity (perm and inv_perm are not "
            "inverses)", field="inv_perm", path=path)


def _check_nnz(nnz, bin_bounds, bin_degrees, n_cols, hard_max, path):
    """nnz: int leaf, trailing axis ``n_cols`` in LAYOUT order, every true
    degree within [0, hard_max] and <= its own bin's padded degree."""
    a = _as_host(nnz)
    if not np.issubdtype(a.dtype, np.integer):
        raise LayoutStructureError(
            f"dtype {a.dtype} is not integral", field="nnz", path=path)
    if a.shape[-1] != n_cols:
        raise LayoutStructureError(
            f"trailing axis {a.shape[-1]} != {n_cols} columns",
            field="nnz", path=path)
    flat = a.reshape(-1, n_cols)
    if flat.size and int(flat.min()) < 0:
        raise LayoutCountError("negative degree", field="nnz", path=path)
    if flat.size and int(flat.max()) > hard_max:
        raise LayoutCountError(
            f"degree {int(flat.max())} exceeds physical max {hard_max}",
            field="nnz", path=path)
    for b, ((s, e), Lb) in enumerate(zip(bin_bounds, bin_degrees)):
        seg = flat[:, s:e]
        if seg.size and int(seg.max()) > Lb:
            raise LayoutCountError(
                f"true degree {int(seg.max())} exceeds the bin's padded "
                f"degree L={Lb} (bins swapped or padded arrays "
                "truncated?)", field="nnz", bin=b, path=path)


def _bounds_of(sizes):
    out, start = [], 0
    for s in sizes:
        out.append((start, start + s))
        start += s
    return out


def _check_scales(layout, allowed_shapes, path):
    """Quantization invariants shared by both layouts: integer values and
    ``scales`` must come together; per bin the scale leaf must be float,
    finite, non-negative, and of one of the ``allowed_shapes(bin)`` forms
    (the rank encodes the scale granularity — see ``core.quant``)."""
    int_values = any(
        np.issubdtype(np.asarray(v).dtype, np.integer)
        for v in layout.values)
    if layout.scales is None:
        if int_values:
            raise LayoutQuantError(
                "integer values without scales (quantized layout missing "
                "its dequantization leaves)", field="scales", path=path)
        return
    if not int_values:
        raise LayoutQuantError(
            f"scales present on {np.asarray(layout.values[0]).dtype} "
            "values (only int values are quantized)", field="scales",
            path=path)
    if len(layout.scales) != len(layout.values):
        raise LayoutQuantError(
            f"{len(layout.scales)} scale bin(s) vs "
            f"{len(layout.values)} value bin(s)", field="scales", path=path)
    for b, s in enumerate(layout.scales):
        sa = _as_host(s)
        if not np.issubdtype(sa.dtype, np.floating):
            raise LayoutQuantError(
                f"dtype {sa.dtype} is not floating", field="scales", bin=b,
                path=path)
        if tuple(sa.shape) not in allowed_shapes(b):
            raise LayoutQuantError(
                f"shape {tuple(sa.shape)} is none of the granularity "
                f"forms {allowed_shapes(b)}", field="scales", bin=b,
                path=path)
        if sa.size and not np.all(np.isfinite(sa)):
            raise LayoutQuantError(
                "non-finite scale entries", field="scales", bin=b,
                path=path)
        if sa.size and float(sa.min()) < 0:
            raise LayoutQuantError(
                f"negative scale {float(sa.min())}", field="scales", bin=b,
                path=path)


def _check_values_finite(layout, path):
    """Every FLOAT ``values`` bin must be fully finite: padding slots are
    zeros, live blocks are real weights, and neither has any business
    holding NaN/Inf (integer bins are covered by the scale checks — int8
    cannot encode a non-finite).  The one corruption class the structural
    checks cannot see."""
    for b, v in enumerate(layout.values):
        va = _as_host(v)
        if np.issubdtype(va.dtype, np.integer):
            continue
        if not np.issubdtype(va.dtype, np.floating):
            va = va.astype(np.float32)   # bfloat16 etc: widen losslessly
        if va.size and not np.all(np.isfinite(va)):
            bad = int(np.size(va) - np.count_nonzero(np.isfinite(va)))
            raise LayoutNumericsError(
                f"{bad} non-finite value entr{'y' if bad == 1 else 'ies'}",
                field="values", bin=b, path=path)


def _check_sharded(layout, n_cols, n_cols_name, path):
    """Cross-shard invariants shared by both layouts when
    ``layout.n_shards`` = S > 0: S must tile the column axis; ``nnz`` must
    carry the (S, cols/S) trailing axes; ``perm``/``inv_perm`` are
    REQUIRED (``merge_shards`` gathers through them) and ``perm`` must be
    (..., S, cols/S) whose flattened last two axes are a permutation of
    range(cols) — one shard claiming a column of another (or a column
    twice) is exactly the corruption that would silently scramble the
    merged output.  Returns cols-per-shard for the caller's bin checks."""
    S = layout.n_shards
    if S < 1 or n_cols % S:
        raise LayoutGeometryError(
            f"n_shards={S} does not divide {n_cols_name}={n_cols}",
            field="n_shards", path=path)
    per = n_cols // S
    a = _as_host(layout.nnz)
    if a.ndim < 2 or a.shape[-2:] != (S, per):
        raise LayoutStructureError(
            f"nnz shape {a.shape} does not end in the shard axes "
            f"(S={S}, {n_cols_name}/S={per})", field="nnz", path=path)
    if layout.perm is None or layout.inv_perm is None:
        raise LayoutPermutationError(
            "sharded layout requires perm/inv_perm (merge_shards gathers "
            "through them)", field="perm", path=path)
    p = _as_host(layout.perm)
    if p.ndim < 2 or p.shape[-2:] != (S, per):
        raise LayoutStructureError(
            f"perm shape {p.shape} does not end in the shard axes "
            f"(S={S}, {n_cols_name}/S={per})", field="perm", path=path)
    return per


def _validate_packed(layout: PackedLayout, path):
    bk, bn = layout.block
    K, N = layout.shape
    S = layout.n_shards
    if bk <= 0 or bn <= 0 or K <= 0 or N <= 0:
        raise LayoutGeometryError(
            f"non-positive geometry block={layout.block} "
            f"shape={layout.shape}", field="block", path=path)
    if K % bk or N % bn:
        raise LayoutGeometryError(
            f"block {layout.block} does not divide shape {layout.shape}",
            field="block", path=path)
    Kb, Nb = K // bk, N // bn
    cols = _check_sharded(layout, Nb, "Nb", path) if S else Nb
    if not layout.values or len(layout.values) != len(layout.k_idx):
        raise LayoutStructureError(
            f"{len(layout.values)} value bin(s) vs "
            f"{len(layout.k_idx)} k_idx bin(s)", field="values", path=path)
    lead = np.shape(layout.values[0])[:-4]
    for b, (v, k) in enumerate(zip(layout.values, layout.k_idx)):
        vs, ks = np.shape(v), np.shape(k)
        if len(vs) < 4 or vs[-2:] != (bk, bn):
            raise LayoutStructureError(
                f"values shape {vs} does not end in block {(bk, bn)}",
                field="values", bin=b, path=path)
        if S and (len(vs) < 5 or vs[-5] != S):
            raise LayoutStructureError(
                f"values shape {vs} lacks the shard axis S={S} before the "
                f"per-bin (nb_b, L_b, bk, bn) dims", field="values", bin=b,
                path=path)
        if vs[:-4] != lead:
            raise LayoutStructureError(
                f"stack dims {vs[:-4]} != bin-0 stack dims {lead}",
                field="values", bin=b, path=path)
        if ks != vs[:-2]:
            raise LayoutStructureError(
                f"k_idx shape {ks} != values slot shape {vs[:-2]}",
                field="k_idx", bin=b, path=path)
        ka = _as_host(k)
        if not np.issubdtype(ka.dtype, np.integer):
            raise LayoutStructureError(
                f"dtype {ka.dtype} is not integral", field="k_idx", bin=b,
                path=path)
        if ka.size and (int(ka.min()) < 0 or int(ka.max()) >= Kb):
            raise LayoutIndexError(
                f"k_idx range [{int(ka.min())}, {int(ka.max())}] outside "
                f"[0, Kb={Kb})", field="k_idx", bin=b, path=path)
    if sum(layout.bin_sizes) != cols:
        raise LayoutGeometryError(
            f"bin sizes {layout.bin_sizes} sum to "
            f"{sum(layout.bin_sizes)}, not "
            f"{'Nb/S' if S else 'Nb'}={cols}", field="values",
            path=path)
    _check_nnz(layout.nnz, _bounds_of(layout.bin_sizes),
               layout.bin_degrees, cols, Kb, path)
    if S:
        p = _as_host(layout.perm)
        _check_perm_pair(p.reshape(p.shape[:-2] + (Nb,)),
                         layout.inv_perm, Nb, path)
    else:
        _check_perm_pair(layout.perm, layout.inv_perm, Nb, path)
    if layout.conv_taps is not None:
        _check_conv_taps(layout.conv_taps, Kb, bk, path)
    # quantization: "block" granularity = one scale per stored block
    # (values shape minus the (bk, bn) block), "out" = one per block
    # column (additionally minus the degree axis)
    _check_scales(
        layout,
        lambda b: (np.shape(layout.values[b])[:-2],
                   np.shape(layout.values[b])[:-3]),
        path)
    _check_values_finite(layout, path)


def _check_conv_taps(conv_taps, Kb, bk, path):
    """conv_taps must be exactly the ``core.bcs.conv_tap_table`` of SOME
    (kh, kw, C) geometry with Kb blocks of bk rows — reconstruct the
    implied geometry and compare table-for-table."""
    from repro.core import bcs as BCS

    if len(conv_taps) != Kb:
        raise LayoutAuxError(
            f"{len(conv_taps)} tap entries for Kb={Kb} K-blocks",
            field="conv_taps", path=path)
    try:
        triples = [(int(dy), int(dx), int(c0)) for dy, dx, c0 in conv_taps]
    except (TypeError, ValueError) as e:
        raise LayoutAuxError(f"entries are not (dy, dx, c0) triples: {e}",
                             field="conv_taps", path=path) from e
    # channel count implied by how many K-blocks share tap (0, 0)
    c_blocks = sum(1 for dy, dx, _ in triples if (dy, dx) == (0, 0))
    kh = max(dy for dy, _, _ in triples) + 1
    kw = max(dx for _, dx, _ in triples) + 1
    C = c_blocks * bk
    if C == 0 or Kb * bk != kh * kw * C:
        raise LayoutAuxError(
            f"implied geometry (kh={kh}, kw={kw}, C={C}) does not tile "
            f"K={Kb * bk}", field="conv_taps", path=path)
    expect = BCS.conv_tap_table(kh, kw, C, bk)
    if tuple(triples) != expect:
        raise LayoutAuxError(
            f"table is not conv_tap_table(kh={kh}, kw={kw}, C={C}, "
            f"bk={bk})", field="conv_taps", path=path)


def _validate_tap(layout: TapLayout, path):
    K, P = layout.shape
    group = layout.group
    if group <= 0 or K <= 0 or P <= 0:
        raise LayoutGeometryError(
            f"non-positive geometry group={group} shape={layout.shape}",
            field="group", path=path)
    if P % group:
        raise LayoutGeometryError(
            f"group {group} does not divide P={P}", field="group",
            path=path)
    G = P // group
    cols = _check_sharded(layout, G, "G", path) if layout.n_shards else G
    if not layout.values or len(layout.values) != len(layout.t_idx):
        raise LayoutStructureError(
            f"{len(layout.values)} value bin(s) vs "
            f"{len(layout.t_idx)} t_idx bin(s)", field="values", path=path)
    if layout.k_full is not None and len(layout.k_full) != len(layout.values):
        raise LayoutStructureError(
            f"{len(layout.k_full)} k_full bin(s) vs "
            f"{len(layout.values)} value bin(s)", field="k_full", path=path)
    alive = _as_host(layout.alive)
    if alive.ndim != 1 or alive.size == 0:
        raise LayoutStructureError(
            f"alive must be a non-empty 1-D index, got shape "
            f"{alive.shape}", field="alive", path=path)
    if not np.issubdtype(alive.dtype, np.integer):
        raise LayoutStructureError(
            f"dtype {alive.dtype} is not integral", field="alive",
            path=path)
    if int(alive.min()) < 0 or int(alive.max()) >= K:
        raise LayoutIndexError(
            f"alive range [{int(alive.min())}, {int(alive.max())}] "
            f"outside [0, K={K})", field="alive", path=path)
    if alive.size > 1 and not np.all(np.diff(alive) > 0):
        raise LayoutIndexError(
            "alive rows are not strictly increasing (band gather order "
            "broken)", field="alive", path=path)
    R = alive.size
    S = layout.n_shards
    for b, (v, t) in enumerate(zip(layout.values, layout.t_idx)):
        vs, ts = np.shape(v), np.shape(t)
        want_nd = 4 if S else 3
        if len(vs) != want_nd or vs[-1] != group:
            raise LayoutStructureError(
                f"values shape {vs} is not "
                f"{'(S, G_b, L_b, group)' if S else '(G_b, L_b, group)'} "
                f"with group={group}", field="values", bin=b, path=path)
        if S and vs[0] != S:
            raise LayoutStructureError(
                f"values shape {vs} leading shard axis != S={S}",
                field="values", bin=b, path=path)
        if ts != vs[:-1]:
            raise LayoutStructureError(
                f"t_idx shape {ts} != values slot shape {vs[:-1]}",
                field="t_idx", bin=b, path=path)
        ta = _as_host(t)
        if not np.issubdtype(ta.dtype, np.integer):
            raise LayoutStructureError(
                f"dtype {ta.dtype} is not integral", field="t_idx", bin=b,
                path=path)
        if ta.size and (int(ta.min()) < 0 or int(ta.max()) >= R):
            raise LayoutIndexError(
                f"t_idx range [{int(ta.min())}, {int(ta.max())}] outside "
                f"the alive band [0, {R})", field="t_idx", bin=b, path=path)
        if layout.k_full is not None:
            kf = _as_host(layout.k_full[b])
            if kf.shape != ta.shape:
                raise LayoutStructureError(
                    f"k_full shape {kf.shape} != t_idx shape {ta.shape}",
                    field="k_full", bin=b, path=path)
            if not np.array_equal(kf, alive[ta]):
                raise LayoutAuxError(
                    "k_full != alive[t_idx] (precomputed full-band rows "
                    "disagree with the alive gather)", field="k_full",
                    bin=b, path=path)
    if sum(layout.bin_sizes) != cols:
        raise LayoutGeometryError(
            f"bin sizes {layout.bin_sizes} sum to "
            f"{sum(layout.bin_sizes)}, not "
            f"{'G/S' if S else 'G'}={cols}", field="values",
            path=path)
    _check_nnz(layout.nnz, _bounds_of(layout.bin_sizes),
               layout.bin_degrees, cols, R, path)
    if S:
        p = _as_host(layout.perm)
        _check_perm_pair(p.reshape(p.shape[:-2] + (G,)),
                         layout.inv_perm, G, path)
    else:
        _check_perm_pair(layout.perm, layout.inv_perm, G, path)
    # quantization: "block" granularity = one scale per tap slot (G_b,
    # L_b); "out" = one per filter in the broadcastable (G_b, 1, group)
    _check_scales(
        layout,
        lambda b: (np.shape(layout.values[b])[:-1],
                   (np.shape(layout.values[b])[0], 1, group)),
        path)
    _check_values_finite(layout, path)


def validate_layout(layout, *, path=None):
    """Check every structural invariant of one layout; raise the matching
    ``LayoutError`` subclass on the first violation.

    ``path`` tags errors with the layer the layout belongs to (purely for
    the log/fallback message).  Returns the layout so calls can chain.
    """
    if isinstance(layout, PackedLayout):
        _validate_packed(layout, path)
    elif isinstance(layout, TapLayout):
        _validate_tap(layout, path)
    else:
        raise LayoutStructureError(
            f"not a PackedLayout/TapLayout: {type(layout).__name__}",
            field="layout", path=path)
    return layout


def validate_tree(exec_params) -> int:
    """Validate every ``"packed"`` entry of an exec-param tree.

    Returns the number of layouts checked; raises the first violation's
    ``LayoutError`` (tagged with the layer path).  ``DegradedLayer``
    sentinels are skipped: their layout was already validated, failed,
    and was retired to the masked-dense path.
    """
    count = 0

    def _walk(node, path):
        nonlocal count
        if not isinstance(node, dict):
            return
        packed = node.get("packed")
        if packed is not None and not isinstance(packed,
                                                 (dict, DegradedLayer)):
            validate_layout(packed, path=f"{path}/packed" if path
                            else "packed")
            count += 1
        for k, v in node.items():
            if k != "packed":
                _walk(v, f"{path}/{k}" if path else k)

    _walk(exec_params, "")
    return count
