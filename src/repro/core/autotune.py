"""Auto-tuning (paper Appendix A.2), TPU edition.

The paper tunes tiling/unroll/data-placement with a genetic algorithm for
OpenCL.  On TPU the tunable space is the Pallas kernel's (bm, bk, bn) tile
shape — small and discrete (multiples of the (8,128) VREG tile bounded by
VMEM), so exhaustive sweep with the latency model replaces the GA; the same
entry points can re-rank by measured wall time on real hardware."""
from __future__ import annotations

import itertools

from repro.core.latency_model import V5E, TPUTarget

VMEM_BYTES = 64 * 1024 * 1024   # usable VMEM budget half of 128MB v5e


def tile_candidates(M, K, N, dtype_bytes=2):
    ms = [m for m in (128, 256, 512) if M % m == 0 or m >= M]
    ks = [k for k in (128, 256, 512) if K % k == 0]
    ns = [n for n in (128, 256, 512) if N % n == 0]
    for bm, bk, bn in itertools.product(ms, ks, ns):
        vmem = (bm * bk + bk * bn + bm * bn * 2) * dtype_bytes * 2  # dbl buf
        if vmem <= VMEM_BYTES:
            yield (min(bm, M), bk, bn)


def tune_tiles(M, K, N, density=1.0, target: TPUTarget = V5E,
               dtype_bytes=2):
    """Pick (bm, bk, bn) minimizing modeled time: MXU-aligned compute +
    HBM streaming + per-step overhead, weights streamed once per M-tile."""
    best, best_t = None, float("inf")
    for bm, bk, bn in tile_candidates(M, K, N, dtype_bytes):
        steps = max(1, M // bm) * max(1, N // bn) * max(
            1, int(K // bk * density))
        flops = 2 * M * K * N * density
        t_c = flops / target.peak_flops
        w_bytes = K * N * density * dtype_bytes * max(1, M // bm)
        x_bytes = M * K * dtype_bytes * max(1, N // bn)
        t_m = (w_bytes + x_bytes) / target.hbm_bw
        t = max(t_c, t_m) + steps * target.step_overhead
        if t < best_t:
            best, best_t = (bm, bk, bn), t
    return best, best_t
