"""Symmetric int8 quantization of the packed interchange layouts.

The NPAS observation (PAPERS.md) is that compiler-aware pruning compounds
with quantization, and the implicit-GEMM work (PR 5) made HBM *value*
traffic the modeled bottleneck of the sparse serving path — so halving (or
quartering) bytes-per-value attacks exactly the dominant roofline term.
This module converts a float ``core.packed.PackedLayout``/``TapLayout``
into the same layout with int8 values plus per-group fp32 scales attached
as a new ``scales`` leaf tuple; everything else (indices, degree bins,
perm, geometry aux) is untouched, so the quantized layout drops into every
existing consumer and the Pallas kernels dequantize in-kernel on top of
the unchanged fp32 accumulation.

Scheme: symmetric linear, ``q = clip(round(v / s), -127, 127)`` with
``s = maxabs(group) / 127`` — no zero point, so zero weights (the pruned
and padding slots both layouts rely on multiplying to nothing) stay
exactly zero.  All-zero groups store scale 0 (there is nothing to
recover; the kernels multiply q=0 by s=0).

Granularity (``scale_granularity``):

  * ``"block"`` (default): one scale per stored unit — per (bk, bn) BCS
    block (``PackedLayout`` scales (..., nb_b, L_b)) or per tap slot
    (``TapLayout`` scales (G_b, L_b)).  Finest error, scale traffic is
    one fp32 per block/slot.
  * ``"out"``: one scale per output column — per BCS block column
    (``PackedLayout`` scales (..., nb_b)) or per filter (``TapLayout``
    scales (G_b, 1, group)).  Coarser error, negligible scale storage —
    the right choice for group=1 tap layouts, where a per-slot scale
    would cost 4 bytes per single stored value.

The granularity is recoverable from the scale ranks alone (see
``core.packed``), so it needs no extra static aux; ``core.validate``
enforces the shape contract and ``serve.artifacts`` serializes the scale
leaves like any other.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.packed import PackedLayout, TapLayout

QMAX = 127.0
GRANULARITIES = ("block", "out")


def _scale_and_cast(v, axes):
    """Quantize one bin's value array over ``axes`` (the reduced group
    axes): returns (int8 values, fp32 scales with the reduced axes
    dropped).  All-zero groups get scale 0 and quantize to all-zero."""
    v = np.asarray(v, np.float32)
    maxabs = np.max(np.abs(v), axis=axes)
    scale = (maxabs / QMAX).astype(np.float32)
    safe = np.where(scale > 0, scale, 1.0)
    safe = np.expand_dims(safe, axes)
    q = np.clip(np.rint(v / safe), -QMAX, QMAX).astype(np.int8)
    return q, scale


def quantize_layout(layout, *, value_dtype="int8",
                    scale_granularity="block"):
    """Quantize a float layout's values to ``value_dtype`` (only "int8"),
    attaching the per-bin fp32 ``scales`` leaves.

    Works on both layout kinds and on stacked ``PackedLayout`` leaves
    (leading layer/expert dims quantize per-slice-group like any other).
    Returns the quantized layout; a layout that already carries scales is
    rejected (double quantization would silently square the error).
    """
    if value_dtype != "int8":
        raise ValueError(f"unsupported value_dtype {value_dtype!r} "
                         "(only 'int8')")
    if scale_granularity not in GRANULARITIES:
        raise ValueError(f"unsupported scale_granularity "
                         f"{scale_granularity!r} (one of {GRANULARITIES})")
    if isinstance(layout, PackedLayout):
        # values (..., nb_b, L_b, bk, bn): "block" reduces the (bk, bn)
        # trailing block, "out" additionally the L (column-degree) axis
        axes = (-2, -1) if scale_granularity == "block" else (-3, -2, -1)
    elif isinstance(layout, TapLayout):
        # values (G_b, L_b, group): "block" reduces the per-slot filter
        # axis; "out" keeps a broadcastable (G_b, 1, group) per-filter form
        axes = (-1,) if scale_granularity == "block" else (-2,)
    else:
        raise TypeError(f"not a packable layout: {type(layout).__name__}")
    if layout.scales is not None:
        raise ValueError("layout is already quantized (scales present)")
    values, scales = [], []
    for v in layout.values:
        q, s = _scale_and_cast(v, axes)
        if isinstance(layout, TapLayout) and scale_granularity == "out":
            s = s[:, None, :]          # keep the broadcastable rank-3 form
        values.append(jnp.asarray(q))
        scales.append(jnp.asarray(s))
    return dataclasses.replace(layout, values=tuple(values),
                               scales=tuple(scales))
