"""Reweighted dynamic regularization (paper §4.2, Eq. 1-4).

Reweighted group Lasso [Candes-Wakin-Boyd]: penalty
    R(alpha_i, W_i) = sum_j sum_g || alpha_ijg * group_g(W_ij) ||_F^2
with  alpha_ijg^(t) = 1 / (||group_g(W_ij^t)||_F^2 + eps)
re-estimated every T steps.  Soft constraints -> the compression rate of
each layer AND each block emerges automatically (vs ADMM's manual per-layer
rates — Table 1).

Groups per scheme:
  block / block_row / block_col : per-block rows / columns        (Eq. 2, 3)
  block_punched                 : per-block intra-kernel location (Eq. 4)
  structured_row / _col         : whole-matrix rows / columns
  unstructured                  : individual weights
Pattern-based layers are excluded from the penalty (pattern assignment is
one-shot magnitude-based, as in PatDNN) — see masks_for_spec.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import regularity as R
from repro.models import module as M

import re


@dataclass(frozen=True)
class SchemeChoice:
    scheme: str = "block"
    block: tuple = (64, 128)
    rate: float | None = None        # target rate for one-shot mode
    connectivity: float = 0.0        # pattern-based extra kernel pruning
    value_dtype: str | None = None   # serving precision pick (None = keep
    #                                  float values; "int8" = quantized
    #                                  packed values, see core.quant)


# A prune spec is an ordered list of (path-regex, SchemeChoice); first match
# wins; non-matching leaves are never pruned.
PruneSpec = list


@dataclass(frozen=True)
class ReweightedConfig:
    spec: tuple                      # PruneSpec as tuple for hashability
    lam: float = 1e-4
    eps: float = 1e-4
    reweight_every: int = 20


def match(spec, path: str) -> SchemeChoice | None:
    for pat, choice in spec:
        if re.search(pat, path):
            return choice
    return None


def _iter_prunable(params, spec):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        s = M.path_str(path)
        choice = match(spec, s)
        if choice is not None and choice.scheme not in ("none", "pattern") \
                and leaf.ndim >= 2:
            yield s, leaf, choice


def group_sqnorms(w, choice: SchemeChoice) -> dict:
    """Returns {group_kind: sqnorm array} for the penalty groups of ``w``."""
    sq = jnp.square(w.astype(jnp.float32))
    sch = choice.scheme
    if sch == "unstructured":
        return {"w": sq}
    if sch == "structured_row":
        return {"row": jnp.sum(sq, axis=-1)}
    if sch == "structured_col":
        return {"col": jnp.sum(sq, axis=-2)}
    if sch in ("block", "block_row", "block_col"):
        bp, bq = choice.block
        wb = R._to_blocks(sq, bp, bq)             # (..., Pb, Qb, bp, bq)
        out = {}
        if sch in ("block", "block_row"):
            out["row"] = jnp.sum(wb, axis=-1)     # (..., Pb, Qb, bp)
        if sch in ("block", "block_col"):
            out["col"] = jnp.sum(wb, axis=-2)     # (..., Pb, Qb, bq)
        return out
    if sch == "block_punched":
        bp, bq = choice.block
        P, Q, Kh, Kw = w.shape
        wb = sq.reshape(P // bp, bp, Q // bq, bq, Kh, Kw)
        return {"punch": jnp.sum(wb, axis=(1, 3))}
    raise ValueError(sch)


def init_alphas(params, spec):
    out = {}
    for path, leaf, choice in _iter_prunable(params, spec):
        out[path] = {k: jnp.ones(v.shape, jnp.float32)
                     for k, v in group_sqnorms(leaf, choice).items()}
    return out


def update_alphas(params, cfg: ReweightedConfig):
    """alpha^(t) = 1 / (||group||_F^2 + eps) — run every reweight_every
    steps (outside the train jit, or as its own jit)."""
    out = {}
    for path, leaf, choice in _iter_prunable(params, cfg.spec):
        out[path] = {k: 1.0 / (v + cfg.eps)
                     for k, v in group_sqnorms(leaf, choice).items()}
    return out


def penalty(params, alphas, cfg: ReweightedConfig):
    """Eq. (1) regularization term: sum over layers / blocks / groups of
    alpha * ||group||_F^2 (alpha held constant between reweightings)."""
    total = jnp.zeros((), jnp.float32)
    for path, leaf, choice in _iter_prunable(params, cfg.spec):
        if path not in alphas:
            continue
        sqs = group_sqnorms(leaf, choice)
        for k, sq in sqs.items():
            total = total + jnp.sum(alphas[path][k] * sq)
    return total


def global_threshold(params, spec, target_rate: float) -> float:
    """One threshold tau over ALL group norms such that ~target_rate of
    groups fall below it — the automatic per-layer/per-block compression
    rates then emerge from where the small groups happen to live.

    Norms are normalized by each layer's MEAN group norm (scale
    invariance): layers initialized at different scales (embeddings vs
    fan-in projections) compete on relative group importance, not raw
    magnitude — otherwise a small-scale layer dies wholesale.  The
    reweighted alphas (1/norm^2) create the within-layer bimodality that
    the threshold then cuts."""
    all_norms = []
    for _, leaf, choice in _iter_prunable(params, spec):
        for sq in group_sqnorms(leaf, choice).values():
            rel = sq / (jnp.mean(sq) + 1e-30)
            all_norms.append(rel.reshape(-1))
    if not all_norms:
        return 0.0
    cat = jnp.concatenate(all_norms)
    return float(jnp.quantile(cat, target_rate))


def masks_for_spec(params, spec, threshold=None, default_rate=None):
    """Full-structure mask tree: {0,1} masks for prunable leaves, scalar 1.0
    sentinels elsewhere (so apply_masks is a plain tree_map)."""
    one = jnp.ones((), jnp.float32)

    def build(path, leaf):
        s = M.path_str(path)
        choice = match(spec, s)
        if choice is None or choice.scheme == "none" or leaf.ndim < 2:
            return one
        if choice.scheme == "pattern":
            if leaf.ndim == 4 and leaf.shape[-2:] == (3, 3):
                return R.pattern_mask(leaf, choice.connectivity)
            if leaf.ndim == 4 and choice.connectivity > 0:
                # the 8-pattern set is 3x3-only (§2.1.1); other kernel
                # sizes keep the scheme's connectivity (whole-kernel) half
                return R.connectivity_mask(leaf, rate=choice.connectivity)
            return one
        if threshold is not None:
            # global_threshold works on layer-mean-normalized sqnorms;
            # rescale back to this leaf's raw group sqnorm scale.
            sq1 = group_sqnorms(leaf, choice)
            mean_sq = float(jnp.mean(next(iter(sq1.values()))))
            return R.make_mask(leaf, choice.scheme, choice.block,
                               threshold=threshold * (mean_sq + 1e-30))
        rate = choice.rate if choice.rate is not None else default_rate
        return R.make_mask(leaf, choice.scheme, choice.block, rate=rate,
                           connectivity_rate=choice.connectivity)

    return jax.tree_util.tree_map_with_path(build, params)


def block_masks_from(params, spec, block, keep_fn):
    """Shared scaffold for whole-(bk, bn)-block mask trees: spec matching,
    sentinel handling, block-tiling guard, and block->element expansion.
    ``keep_fn(path_str, leaf, (Pb, Qb) grid shape) -> bool keep grid``.
    ``block=None`` uses each matched rule's own ``choice.block`` — what the
    serving CLI needs when one spec mixes block shapes (e.g. FC (16, 16)
    next to the narrower SSM in_proj block)."""

    def build(path, leaf):
        s = M.path_str(path)
        choice = match(spec, s)
        if choice is None or leaf.ndim < 2:
            return jnp.ones((), jnp.float32)
        bk, bn = block if block is not None else choice.block
        *lead, P, Q = leaf.shape
        if P % bk or Q % bn:     # block must tile the leaf (e.g. phi3 d=60)
            return jnp.ones((), jnp.float32)
        keep = keep_fn(s, leaf, (*lead, P // bk, Q // bn))
        return jnp.repeat(jnp.repeat(keep, bk, -2), bn, -1).astype(jnp.float32)

    return jax.tree_util.tree_map_with_path(build, params)


def random_block_masks(params, spec, block=(16, 16), keep_prob=0.5, seed=0):
    """Bernoulli whole-block masks on spec-matched leaves, scalar sentinels
    elsewhere — the structured-collapse scaffolding used by the serving
    demos, e2e benches, and compile_model tests (real pipelines get masks
    from ``masks_for_spec``/``pruner``).  Keys derive from crc32(path) +
    seed, NOT ``hash()``, so the packed/not-packed outcome is stable across
    processes."""
    import zlib

    def keep_fn(s, leaf, grid):
        key = jax.random.PRNGKey((zlib.crc32(s.encode()) + seed) % (2 ** 31))
        return jax.random.uniform(key, grid) < keep_prob

    return block_masks_from(params, spec, block, keep_fn)


def punched_conv_masks(params, spec, block=(8, 8), rate=0.5):
    """One-shot magnitude block-punched masks (§4.1.2) on spec-matched 4-D
    (P, Q, Kh, Kw) conv leaves, scalar sentinels elsewhere — the conv
    analogue of ``magnitude_block_masks``: the same intra-kernel position is
    pruned across every kernel of a (bp, bq) kernel block, which is exactly
    the structure ``serve.compile`` lowers into dead BCS blocks.
    ``block=None`` punches each leaf at its matched rule's own
    ``choice.block`` (keeping mask and packing block in lockstep, as for
    the FC builders).  Leaves the block cannot tile (e.g. a 3-channel
    stem) stay unpruned."""

    def build(path, leaf):
        s = M.path_str(path)
        choice = match(spec, s)
        if choice is None or leaf.ndim != 4:
            return jnp.ones((), jnp.float32)
        bp, bq = block if block is not None else choice.block
        P, Q = leaf.shape[:2]
        if P % bp or Q % bq:
            return jnp.ones((), jnp.float32)
        return R.block_punched_mask(leaf, (bp, bq), rate=rate)

    return jax.tree_util.tree_map_with_path(build, params)


def magnitude_block_masks(params, spec, block=(16, 16), rate=0.5):
    """One-shot magnitude pruning at whole-block granularity: the
    ``rate``-fraction of blocks with the smallest L2 norms die outright —
    the structured collapse the BCS executor skips.  ``block=None`` prunes
    each matched leaf at its rule's own ``choice.block``."""

    def keep_fn(s, leaf, grid):
        sq = jnp.square(leaf.astype(jnp.float32))
        *lead, P, Q = leaf.shape
        bk, bn = P // grid[-2], Q // grid[-1]
        g = sq.reshape(*lead, P // bk, bk, Q // bn, bn).sum(axis=(-3, -1))
        return g > jnp.quantile(g.reshape(-1), rate)

    return block_masks_from(params, spec, block, keep_fn)


def sparsity_report(params, masks) -> dict:
    """Per-layer + overall density/compression."""
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_leaves(masks)
    rep, tot_w, tot_kept = {}, 0, 0.0
    for (path, p), m in zip(flat_p, flat_m):
        s = M.path_str(path)
        if m.shape == ():   # sentinel
            tot_w += p.size
            tot_kept += p.size
            continue
        kept = float(jnp.sum(m))
        rep[s] = {"density": kept / m.size,
                  "compression": m.size / max(kept, 1.0)}
        tot_w += p.size
        tot_kept += kept
    rep["__overall__"] = {"density": tot_kept / tot_w,
                          "compression": tot_w / max(tot_kept, 1.0)}
    return rep
