"""Pruning regularities (paper §2.1.1 + §4.1) as mask generators.

All five schemes from the paper:
  - unstructured                (Fig 1 a,b)       — any-location magnitude
  - structured row / column     (Fig 1 c,d)       — whole-matrix granularity
  - pattern-based               (Fig 1 e)         — 3x3 CONV only: 4-entry
      kernel patterns from a fixed 8-pattern set + connectivity pruning
  - block-based                 (Fig 1 g, §4.1.1) — FC: independent row/col
      pruning inside equal (p×q) blocks
  - block-punched               (Fig 1 f, §4.1.2) — CONV: same intra-kernel
      positions pruned across all kernels of a (p×q)-kernel block

Conventions: FC weights are (..., in, out) with arbitrary leading batch dims
(scanned layer stacks, MoE expert dims).  CONV weights are (P, Q, Kh, Kw) =
(filters, in_channels, kh, kw).  Masks are float32 {0,1} of the weight shape.

Two selection modes everywhere:
  rate=r        prune the r-fraction of groups with smallest L2 norms
  threshold=t   prune groups with squared-norm < t (the reweighted
                algorithm's automatic-rate mode, §4.2)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

SCHEMES = ("none", "unstructured", "structured_row", "structured_col",
           "pattern", "block", "block_row", "block_col", "block_punched")


# ---------------------------------------------------------------------------
# Block partitioning helpers (last-2-dims blocks, leading dims = batch)
# ---------------------------------------------------------------------------

def _to_blocks(w, bp, bq):
    """(..., P, Q) -> (..., Pb, Qb, bp, bq)"""
    *lead, Pd, Qd = w.shape
    assert Pd % bp == 0 and Qd % bq == 0, (w.shape, bp, bq)
    w = w.reshape(*lead, Pd // bp, bp, Qd // bq, bq)
    return jnp.moveaxis(w, -3, -2)      # (..., Pb, Qb, bp, bq)


def _from_blocks(wb):
    """inverse of _to_blocks"""
    *lead, Pb, Qb, bp, bq = wb.shape
    wb = jnp.moveaxis(wb, -2, -3)       # (..., Pb, bp, Qb, bq)
    return wb.reshape(*lead, Pb * bp, Qb * bq)


def _select(sqnorms, rate=None, threshold=None, axes=None):
    """Keep-mask over groups.  rate prunes the smallest-`rate` fraction
    (computed over `axes`, default: all); threshold keeps sqnorm >= t."""
    if threshold is not None:
        return sqnorms >= threshold
    assert rate is not None
    flat = sqnorms if axes is None else sqnorms
    q = jnp.quantile(flat.astype(jnp.float32).reshape(-1), rate)
    return sqnorms > q


# ---------------------------------------------------------------------------
# Schemes
# ---------------------------------------------------------------------------

def unstructured_mask(w, rate=None, threshold=None):
    sq = jnp.square(w.astype(jnp.float32))
    return _select(sq, rate, threshold).astype(jnp.float32)


def structured_mask(w, rate=None, threshold=None, axis="row"):
    """Whole-matrix row (output-filter) / column pruning — Fig 1(c,d).
    'row' prunes along P (second-to-last dim), 'col' along Q (last dim)."""
    sq = jnp.square(w.astype(jnp.float32))
    if axis == "row":
        g = jnp.sum(sq, axis=-1)                 # (..., P)
        keep = _select(g, rate, threshold)
        return jnp.broadcast_to(keep[..., :, None], w.shape).astype(jnp.float32)
    g = jnp.sum(sq, axis=-2)                     # (..., Q)
    keep = _select(g, rate, threshold)
    return jnp.broadcast_to(keep[..., None, :], w.shape).astype(jnp.float32)


def block_mask(w, block, rate=None, threshold=None, mode="both"):
    """Block-based pruning for FC (§4.1.1): independent row+column pruning
    per (bp×bq) block.  mode in {'row','col','both'}.  Group sq-norms are
    per-block rows/cols; the kept set is chosen globally in the layer
    (auto per-block rates, matching the reweighted soft-constraint)."""
    bp, bq = block
    wb = _to_blocks(w, bp, bq)                    # (..., Pb, Qb, bp, bq)
    sq = jnp.square(wb.astype(jnp.float32))
    keep = jnp.ones(wb.shape, jnp.float32)
    if mode in ("row", "both"):
        g = jnp.sum(sq, axis=-1)                  # (..., Pb, Qb, bp)
        r = rate if mode == "row" else (1 - (1 - rate) ** 0.5 if rate is not None else None)
        k = _select(g, r, threshold)
        keep = keep * k[..., :, None].astype(jnp.float32)
    if mode in ("col", "both"):
        g = jnp.sum(sq, axis=-2)                  # (..., Pb, Qb, bq)
        r = rate if mode == "col" else (1 - (1 - rate) ** 0.5 if rate is not None else None)
        k = _select(g, r, threshold)
        keep = keep * k[..., None, :].astype(jnp.float32)
    return _from_blocks(keep)


def block_punched_mask(w, block, rate=None, threshold=None):
    """Block-punched pruning for CONV (§4.1.2): weights at the same (m,n)
    kernel location across ALL kernels of a (bp×bq)-kernel block are pruned
    together.  w: (P, Q, Kh, Kw)."""
    bp, bq = block
    P, Q, Kh, Kw = w.shape
    assert P % bp == 0 and Q % bq == 0
    wb = w.reshape(P // bp, bp, Q // bq, bq, Kh, Kw)
    sq = jnp.square(wb.astype(jnp.float32))
    g = jnp.sum(sq, axis=(1, 3))                  # (Pb, Qb, Kh, Kw)
    keep = _select(g, rate, threshold)            # same punch across block
    keep = jnp.broadcast_to(keep[:, None, :, None, :, :], wb.shape)
    return keep.reshape(P, Q, Kh, Kw).astype(jnp.float32)


# -- pattern-based (3x3 CONV only) -------------------------------------------

# The canonical 8-pattern set: 4-entry patterns shaped like Gaussian /
# ELoG filters (paper §2.1.1, [53]).  Center + 3 of the 4 edge-adjacent
# cells, and the 4 corner variants.
_P = np.zeros((8, 3, 3), np.float32)
for i, cells in enumerate([
        [(1, 1), (0, 1), (1, 0), (1, 2)],   # T-up
        [(1, 1), (2, 1), (1, 0), (1, 2)],   # T-down
        [(1, 1), (0, 1), (2, 1), (1, 0)],   # T-left
        [(1, 1), (0, 1), (2, 1), (1, 2)],   # T-right
        [(1, 1), (0, 0), (0, 1), (1, 0)],   # corner NW
        [(1, 1), (0, 1), (0, 2), (1, 2)],   # corner NE
        [(1, 1), (1, 0), (2, 0), (2, 1)],   # corner SW
        [(1, 1), (1, 2), (2, 1), (2, 2)],   # corner SE
]):
    for (r, c) in cells:
        _P[i, r, c] = 1.0
PATTERN_SET = jnp.asarray(_P)                     # (8, 3, 3)


def connectivity_mask(w, rate=None, threshold=None):
    """Connectivity pruning alone (PCONV's inter-kernel half): whole (p, q)
    kernels with the smallest L2 norms die, any kernel size.  This is the
    pattern-scheme component that applies beyond 3x3 — ``masks_for_spec``
    routes a ``pattern`` choice on a non-3x3 conv here, and the tap-gather
    executor skips the dead kernels' taps wholesale.  w: (P, Q, Kh, Kw)."""
    sq = jnp.square(w.astype(jnp.float32))
    g = jnp.sum(sq, axis=(-1, -2))                # (P, Q)
    keep = _select(g, rate, threshold)
    return jnp.broadcast_to(keep[..., None, None], w.shape).astype(jnp.float32)


def pattern_mask(w, connectivity_rate=0.0):
    """Kernel-pattern pruning (+optional connectivity pruning) for 3x3 CONV.
    Each kernel gets the pattern from the fixed 8-set that preserves the
    most magnitude; connectivity pruning removes whole kernels (inter-kernel)
    for extra compression.  w: (P, Q, 3, 3)."""
    assert w.shape[-2:] == (3, 3), "pattern-based pruning is 3x3-only (§2.1.1)"
    sq = jnp.square(w.astype(jnp.float32))
    scores = jnp.einsum("pqhw,khw->pqk", sq, PATTERN_SET)   # (P,Q,8)
    best = jnp.argmax(scores, axis=-1)                      # (P,Q)
    mask = PATTERN_SET[best]                                # (P,Q,3,3)
    if connectivity_rate > 0:
        knorm = jnp.sum(sq, axis=(-1, -2))                  # (P,Q)
        q = jnp.quantile(knorm.reshape(-1), connectivity_rate)
        mask = mask * (knorm > q)[..., None, None]
    return mask.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Dispatch + stats
# ---------------------------------------------------------------------------

def make_mask(w, scheme, block=(64, 128), rate=None, threshold=None,
              connectivity_rate=0.0):
    if scheme == "none":
        return jnp.ones(w.shape, jnp.float32)
    if scheme == "unstructured":
        return unstructured_mask(w, rate, threshold)
    if scheme == "structured_row":
        return structured_mask(w, rate, threshold, "row")
    if scheme == "structured_col":
        return structured_mask(w, rate, threshold, "col")
    if scheme == "block":
        return block_mask(w, block, rate, threshold, "both")
    if scheme == "block_row":
        return block_mask(w, block, rate, threshold, "row")
    if scheme == "block_col":
        return block_mask(w, block, rate, threshold, "col")
    if scheme == "block_punched":
        return block_punched_mask(w, block, rate, threshold)
    if scheme == "pattern":
        return pattern_mask(w, connectivity_rate)
    raise ValueError(scheme)


def density(mask) -> float:
    return float(jnp.mean(mask))


def compression_rate(mask) -> float:
    d = density(mask)
    return 1.0 / max(d, 1e-9)


def legal_blocks(P, Q, menu=((4, 4), (8, 16), (16, 32), (32, 64), (64, 128),
                             (128, 32), (128, 64), (128, 128), (128, 256),
                             (256, 256))):
    """Block-size menu restricted to divisors of the layer dims.  On TPU the
    interesting sizes are multiples of the (8,128) VREG tile up to the MXU
    128x128 tile (DESIGN.md §2); small sizes exist to reproduce the paper's
    accuracy/latency trade-off curves."""
    return [(p, q) for (p, q) in menu if P % p == 0 and Q % q == 0]
