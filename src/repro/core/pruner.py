"""Pruning orchestration: the end-to-end compress pipeline (paper Fig 2).

  prune(model) =
    1. map schemes        (rule-based or search-based -> PruneSpec)
    2. reweighted train   (loss + lam * R(alpha, W), alphas re-estimated)
    3. threshold          (global tau -> per-layer/per-block auto rates)
    4. finetune masked    (regain accuracy)

One-shot mode (magnitude -> mask -> short retrain) is the fast proxy the
search-based mapper uses for reward evaluation (§5.1)."""
from __future__ import annotations

from dataclasses import dataclass


from repro.core import reweighted as RW
from repro.train.trainer import apply_masks


@dataclass
class PruneResult:
    params: dict
    masks: dict
    report: dict


def one_shot(params, spec, rate) -> dict:
    """Magnitude one-shot masks at a uniform per-layer group rate."""
    return RW.masks_for_spec(params, spec, default_rate=rate)


def reweighted_prune(params, opt_state, spec, train_step_fn, batch_fn, *,
                     lam=1e-4, eps=1e-4, steps=100, reweight_every=20,
                     target_rate=0.8, finetune_steps=50,
                     verbose=False) -> PruneResult:
    """Full pipeline on an already-built train_step (which must accept
    (params, opt_state, batch, masks, alphas)).  batch_fn(step) -> batch."""
    cfg = RW.ReweightedConfig(spec=tuple(spec), lam=lam, eps=eps,
                              reweight_every=reweight_every)
    alphas = RW.init_alphas(params, spec)
    # phase 1: reweighted regularization training
    for step in range(steps):
        if step % reweight_every == 0 and step > 0:
            alphas = RW.update_alphas(params, cfg)
        params, opt_state, metrics = train_step_fn(
            params, opt_state, batch_fn(step), None, alphas)
        if verbose and step % 20 == 0:
            print(f"  reweighted step {step}: loss "
                  f"{float(metrics['loss']):.4f}")
    # phase 2: automatic thresholds -> masks
    tau = RW.global_threshold(params, spec, target_rate)
    masks = RW.masks_for_spec(params, spec, threshold=tau)
    # phase 3: masked finetune
    for step in range(finetune_steps):
        params, opt_state, metrics = train_step_fn(
            params, opt_state, batch_fn(steps + step), masks, None)
        if verbose and step % 20 == 0:
            print(f"  finetune step {step}: loss "
                  f"{float(metrics['loss']):.4f}")
    params = apply_masks(params, masks)
    return PruneResult(params=params, masks=masks,
                       report=RW.sparsity_report(params, masks))
