"""Search-based pruning-scheme mapping (paper §5.1) — REINFORCE over a
seq2seq policy.

State per layer (paper: {layer type, kernel size, in_ch, out_ch}): a feature
vector [kind-onehot, log M/K/N].  Action per layer (paper: {regularity,
block size}, extended here with serving precision): a triple of
categoricals — scheme (masked to the applicable set), block size, and
value precision (PRECISION_MENU: float vs int8 quantized values, priced
by ``matmul_latency(value_bytes=1)``).  Policy: LSTM decoder over the
layer sequence; policy-gradient with a moving baseline B (Eq. 6); reward
= accuracy-proxy - w * modeled latency — accuracy from one-shot magnitude
pruning + a short retrain (paper uses 2-epoch proxies), latency from the
offline latency model (§5.2.1)."""
from __future__ import annotations


import numpy as np
import jax
import jax.numpy as jnp

from repro.core.latency_model import (V5E, im2col_x_frac, matmul_latency,
                                      pattern_executed_frac)
from repro.core.mapper_rule import LayerDesc
from repro.core.reweighted import SchemeChoice

KINDS = ("fc", "conv3x3", "conv1x1", "convkxk", "dw", "frozen")
SCHEME_MENU = ("none", "unstructured", "structured_row", "pattern", "block",
               "block_punched")
BLOCK_MENU = ((4, 4), (8, 16), (16, 32), (32, 64), (64, 128), (128, 128))
# serving precision of the packed values (None = float; "int8" = the
# quantized layouts of core.quant, priced at value_bytes=1)
PRECISION_MENU = (None, "int8")
# schemes whose packed layouts can carry quantized values — precision
# picks on other schemes are inert (actions_to_spec drops them)
_QUANTIZABLE = ("pattern", "block", "block_row", "block_col",
                "block_punched")


def applicable(kind: str) -> np.ndarray:
    """Boolean mask over SCHEME_MENU per layer kind (paper constraints:
    pattern is 3x3-only; dw/frozen layers are never pruned)."""
    m = np.zeros(len(SCHEME_MENU), bool)
    if kind in ("dw", "frozen"):
        m[0] = True
        return m
    m[:] = True
    if kind != "conv3x3":
        m[SCHEME_MENU.index("pattern")] = False
        m[SCHEME_MENU.index("block_punched")] = kind == "convkxk"
    return m


def layer_features(layers: list[LayerDesc]) -> np.ndarray:
    f = np.zeros((len(layers), len(KINDS) + 3), np.float32)
    for i, ld in enumerate(layers):
        f[i, KINDS.index(ld.kind)] = 1.0
        f[i, -3:] = np.log([ld.M, ld.K, ld.N])
    return f


# -- tiny LSTM policy ---------------------------------------------------------

def policy_init(key, in_dim, hidden=64):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = lambda k, sh: jax.random.normal(k, sh, jnp.float32) * 0.1
    return {"wx": s(k1, (in_dim, 4 * hidden)),
            "wh": s(k2, (hidden, 4 * hidden)),
            "b": jnp.zeros((4 * hidden,), jnp.float32),
            "head_s": s(k3, (hidden, len(SCHEME_MENU))),
            "head_b": s(k4, (hidden, len(BLOCK_MENU))),
            "head_p": s(k5, (hidden, len(PRECISION_MENU)))}


def _lstm_step(p, carry, x):
    h, c = carry
    z = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def sample_mapping(p, feats, app_masks, key):
    """Returns (scheme_idx (L,), block_idx (L,), precision_idx (L,),
    logp scalar)."""
    hidden = p["wh"].shape[0]
    L = feats.shape[0]
    keys = jax.random.split(key, L)

    def body(carry, xs):
        hc, logp = carry
        x, mask, k = xs
        hc, h = _lstm_step(p, hc, x)
        ls = jnp.where(mask, h @ p["head_s"], -1e9)
        k1, k2, k3 = jax.random.split(k, 3)
        a_s = jax.random.categorical(k1, ls)
        logp = logp + jax.nn.log_softmax(ls)[a_s]
        lb = h @ p["head_b"]
        a_b = jax.random.categorical(k2, lb)
        logp = logp + jax.nn.log_softmax(lb)[a_b]
        lp = h @ p["head_p"]
        a_p = jax.random.categorical(k3, lp)
        logp = logp + jax.nn.log_softmax(lp)[a_p]
        return (hc, logp), (a_s, a_b, a_p)

    hc0 = (jnp.zeros((hidden,)), jnp.zeros((hidden,)))
    (_, logp), (a_s, a_b, a_p) = jax.lax.scan(
        body, (hc0, jnp.zeros(())), (feats, app_masks, keys))
    return a_s, a_b, a_p, logp


def mapping_logp(p, feats, app_masks, a_s, a_b, a_p):
    hidden = p["wh"].shape[0]

    def body(carry, xs):
        hc, logp = carry
        x, mask, s, b, pr = xs
        hc, h = _lstm_step(p, hc, x)
        ls = jnp.where(mask, h @ p["head_s"], -1e9)
        lb = h @ p["head_b"]
        lp = h @ p["head_p"]
        logp = (logp + jax.nn.log_softmax(ls)[s]
                + jax.nn.log_softmax(lb)[b] + jax.nn.log_softmax(lp)[pr])
        return (hc, logp), None

    hc0 = (jnp.zeros((hidden,)), jnp.zeros((hidden,)))
    (_, logp), _ = jax.lax.scan(body, (hc0, jnp.zeros(())),
                                (feats, app_masks, a_s, a_b, a_p))
    return logp


def _precision(scheme, a_p, i):
    """Resolve layer i's precision action: the picked value dtype on a
    quantizable scheme, None otherwise (or when no a_p was sampled)."""
    if a_p is None or scheme not in _QUANTIZABLE:
        return None
    return PRECISION_MENU[int(np.asarray(a_p)[i])]


def actions_to_spec(layers, a_s, a_b, a_p=None, rate=None) -> list:
    """Decode sampled action indices into a PruneSpec; ``a_p`` (the
    precision head, optional for legacy two-action callers) becomes each
    choice's ``value_dtype`` on quantizable schemes."""
    spec = []
    for i, (ld, s, b) in enumerate(zip(layers, np.asarray(a_s),
                                       np.asarray(a_b))):
        scheme = SCHEME_MENU[int(s)]
        block = BLOCK_MENU[int(b)]
        # snap block to layer divisibility
        bk = max(1, np.gcd(block[0], ld.K))
        bn = max(1, np.gcd(block[1], ld.N))
        spec.append((ld.path, SchemeChoice(
            scheme, (int(bk), int(bn)), rate=rate,
            value_dtype=_precision(scheme, a_p, i))))
    return spec


def mapping_latency(layers, a_s, a_b, a_p=None, compression=8.0,
                    target=V5E) -> float:
    """Modeled total latency of a sampled mapping — the reward's latency
    term.  Pattern picks are priced at the tap-gather kernel's executed-tap
    fraction (``pattern_executed_frac``), not raw mask density;
    conv-as-GEMM layers (``LayerDesc.taps`` > 1) at the implicit-GEMM
    path's activation traffic (feature map read once — ``im2col_x_frac``),
    not the never-materialized M*K patch bytes; and int8 precision picks
    (``a_p``) at 1 byte per stored value plus the kernels' fp32 scale
    traffic (``matmul_latency(value_bytes=1)``)."""
    t = 0.0
    for i, (ld, s, b) in enumerate(zip(layers, np.asarray(a_s),
                                       np.asarray(a_b))):
        scheme = SCHEME_MENU[int(s)]
        taps = getattr(ld, "taps", 0)
        xf = im2col_x_frac(taps) if taps > 1 else None
        frac = None
        if scheme == "none":
            comp = 1.0
        elif scheme == "pattern":
            frac = pattern_executed_frac()
            comp = 1 / frac
        else:
            comp = compression
        vb = 1 if _precision(scheme, a_p, i) == "int8" else None
        t += ld.count * matmul_latency(
            ld.M, ld.K, ld.N, scheme=scheme, block=BLOCK_MENU[int(b)],
            compression=comp, target=target, value_bytes=vb,
            executed_frac=frac, x_frac=xf)
    return t


def search(layers, evaluate_fn, *, key=None, iters=20, samples=4,
           lr=5e-2, latency_weight=1.0, hidden=32, verbose=False):
    """REINFORCE loop (Eq. 5-6).  evaluate_fn(spec) -> accuracy-proxy in
    [0,1] (e.g. exp(-finetuned loss)).  Returns (best_spec, history)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    feats = jnp.asarray(layer_features(layers))
    app = jnp.asarray(np.stack([applicable(ld.kind) for ld in layers]))
    p = policy_init(jax.random.fold_in(key, 1), feats.shape[1], hidden)
    baseline = 0.0
    best = (None, -np.inf)
    history = []
    sample_jit = jax.jit(lambda pp, k: sample_mapping(pp, feats, app, k))
    grad_fn = jax.jit(jax.grad(
        lambda pp, a_s, a_b, a_p, adv: -adv * mapping_logp(
            pp, feats, app, a_s, a_b, a_p)))
    for it in range(iters):
        key, *ks = jax.random.split(key, samples + 1)
        grads_acc = jax.tree_util.tree_map(jnp.zeros_like, p)
        rewards = []
        for k in ks:
            a_s, a_b, a_p, _ = sample_jit(p, k)
            spec = actions_to_spec(layers, a_s, a_b, a_p)
            acc = evaluate_fn(spec)
            lat = mapping_latency(layers, a_s, a_b, a_p)
            r = acc - latency_weight * lat
            rewards.append(r)
            if r > best[1]:
                best = (spec, r)
            adv = r - baseline
            g = grad_fn(p, a_s, a_b, a_p, adv)
            grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, g)
        baseline = 0.9 * baseline + 0.1 * float(np.mean(rewards))
        p = jax.tree_util.tree_map(lambda w, g: w - lr * g / samples,
                                   p, grads_acc)
        history.append(float(np.mean(rewards)))
        if verbose:
            print(f"  search iter {it}: mean reward {history[-1]:.4f}")
    return best[0], history
