"""Rule-based pruning-scheme mapping (paper §5.2, Fig 8) — training-free.

Workflow per layer (TPU edition, DESIGN.md §2 table):
  1. depthwise conv / conv1d / router / embedding / norms -> NO pruning
     (§5.2.4: cheap + sensitive; router/embed are the LM analogues).
  2. 3x3 CONV -> pattern-based when the task is "hard" (Remark 1), else
     block-punched; other convs -> block-punched.
  3. FC layers (all LM projections) -> block-based; block size = the
     SMALLEST legal block whose modeled latency is within (1+beta) of the
     structured-pruning baseline at equal compression (§5.2.2) — smallest
     because finer granularity = higher accuracy.
  4. Serving precision rides the same pricing: every packable pick is
     re-priced with int8 values (``matmul_latency(value_bytes=1)`` — the
     quantized layouts of ``core.quant``), and the cheaper precision wins
     the layer (``SchemeChoice.value_dtype``).  On the memory-bound layers
     the implicit-GEMM work exposed, int8 roughly halves the dominant
     weight-traffic term at unchanged modeled compute (fp32 accumulation
     in-kernel), so the pick is usually int8 — but MXU-bound layers keep
     float values (no modeled win, so no quantization error for free).
The latency model is the offline artifact (§5.2.1); the whole mapping is
training-free."""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.configs.base import ArchConfig
from repro.core.latency_model import (TPUTarget, V5E, im2col_x_frac,
                                      matmul_latency, pattern_executed_frac,
                                      structured_baseline, conv_as_gemm)
from repro.core.regularity import legal_blocks
from repro.core.reweighted import SchemeChoice


@dataclass(frozen=True)
class LayerDesc:
    path: str            # regex into the param tree
    kind: str            # fc | conv3x3 | conv1x1 | convkxk | dw | frozen
    M: int               # GEMM dims (tokens x K x N)
    K: int
    N: int
    count: int = 1       # layers sharing this desc (scanned stacks)
    taps: int = 0        # Kh*Kw for conv-as-GEMM layers (0 = plain GEMM):
                         # prices activation traffic at the implicit-GEMM
                         # path's feature-map read (im2col_x_frac) instead
                         # of the full M*K patch bytes


def lm_layers(cfg: ArchConfig, tokens: int) -> list[LayerDesc]:
    """Enumerate the prunable GEMMs of an LM-family arch."""
    out = []
    D, F, hd = cfg.d_model, cfg.d_ff, cfg.hd
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "hybrid", "encdec", "vlm"):
        H, KV = cfg.n_heads, cfg.n_kv_heads
        out += [
            LayerDesc(r"attn/wq/w", "fc", tokens, D, H * hd, L),
            LayerDesc(r"attn/w[kv]/w", "fc", tokens, D, KV * hd, 2 * L),
            LayerDesc(r"attn/wo/w", "fc", tokens, H * hd, D, L),
        ]
    if cfg.family == "moe":
        tpe = max(1, tokens * cfg.top_k // cfg.n_experts)
        out += [
            LayerDesc(r"moe/(gate|up)/w", "fc", tpe, D, F, 2 * L),
            LayerDesc(r"moe/down/w", "fc", tpe, F, D, L),
            LayerDesc(r"moe/router", "frozen", tokens, D, cfg.n_experts, L),
        ]
    elif cfg.family in ("dense", "hybrid", "encdec", "vlm"):
        out += [
            LayerDesc(r"ffn/(gate|up)/w", "fc", tokens, D, F, 2 * L),
            LayerDesc(r"ffn/down/w", "fc", tokens, F, D, L),
        ]
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * D
        proj = 2 * d_inner + 2 * cfg.ssm_state + d_inner // cfg.ssm_headdim
        out += [
            LayerDesc(r"ssm/in_proj/w", "fc", tokens, D, proj, L),
            LayerDesc(r"ssm/out_proj/w", "fc", tokens, d_inner, D, L),
            LayerDesc(r"ssm/conv", "dw", tokens, 4, d_inner, L),
        ]
    if cfg.family in ("encdec", "vlm"):
        out += [LayerDesc(r"xattn/wq/w|xattn/wo/w", "fc", tokens, D, H * hd,
                          2 * L)]
    out += [
        LayerDesc(r"head/table", "fc", tokens, D, cfg.vocab, 1),
        LayerDesc(r"embed/table", "frozen", tokens, cfg.vocab, D, 1),
    ]
    return out


def conv_layers(specs) -> list[LayerDesc]:
    """specs: list of (name, feat, in_ch, out_ch, kh, kw, depthwise)."""
    out = []
    for (name, feat, cin, cout, kh, kw, dw) in specs:
        M, K, N = conv_as_gemm(feat, cin, cout, kh, kw)
        kind = "dw" if dw else (
            "conv3x3" if (kh, kw) == (3, 3) else
            "conv1x1" if (kh, kw) == (1, 1) else "convkxk")
        out.append(LayerDesc(name, kind, M, K, N, taps=0 if dw else kh * kw))
    return out


def select_block_size(M, K, N, compression, beta, target: TPUTarget = V5E,
                      menu=None, x_frac=None):
    """§5.2.2: smallest block within (1+beta) of structured latency.
    ``x_frac`` forwards the conv activation-traffic multiplier (the
    implicit-GEMM feature-map read) into the block pricing."""
    base = structured_baseline(M, K, N, compression, target)
    cands = legal_blocks(K, N) if menu is None else \
        [b for b in menu if K % b[0] == 0 and N % b[1] == 0]
    cands = sorted(cands, key=lambda b: b[0] * b[1])
    for b in cands:
        t = matmul_latency(M, K, N, scheme="block", block=b,
                           compression=compression, target=target,
                           x_frac=x_frac)
        if t <= (1 + beta) * base:
            return b, t, base
    b = cands[-1] if cands else (min(K, 128), min(N, 128))
    t = matmul_latency(M, K, N, scheme="block", block=b,
                       compression=compression, target=target, x_frac=x_frac)
    return b, t, base


def _pick_precision(choice, t, *, M, K, N, compression, target,
                    executed_frac=None, x_frac=None):
    """Re-price a packable pick with int8 values (``value_bytes=1``) and
    return (choice, latency) of the cheaper precision — the mapper's
    per-layer precision action.  Strictly-better wins: a compute-bound
    layer whose modeled latency does not move keeps float values, so it
    never pays quantization error for nothing."""
    t_q = matmul_latency(M, K, N, scheme=choice.scheme, block=choice.block,
                         compression=compression, target=target,
                         value_bytes=1, executed_frac=executed_frac,
                         x_frac=x_frac)
    if t_q < t:
        return replace(choice, value_dtype="int8"), t_q
    return choice, t


def map_rules(layers: list[LayerDesc], *, dataset_hard=True, beta=0.2,
              compression=8.0, target: TPUTarget = V5E):
    """Returns (PruneSpec rules, per-layer report) — each rule's
    ``SchemeChoice`` carries the scheme, block, and the precision pick
    (``value_dtype``), all priced by the extended latency model."""
    spec, report = [], []
    for ld in layers:
        if ld.kind in ("dw", "frozen"):
            choice = SchemeChoice("none")
            t = t_base = 0.0
        elif ld.kind == "conv3x3":
            # conv-as-GEMM activation traffic is priced at the implicit
            # kernels' feature-map read (DRAM bytes, not MACs) — the
            # serving path never materializes the M*K patch tensor
            xf = im2col_x_frac(ld.taps or 9)
            if dataset_hard:
                conn = 1 - 4 / 9 / 1.0
                choice = SchemeChoice("pattern", connectivity=conn)
                # rank the pattern pick by what the tap-gather kernel
                # EXECUTES (4-of-9 taps x surviving kernels), not by the
                # raw 4/9 mask density it used to be priced at
                frac = pattern_executed_frac(conn)
                t = matmul_latency(ld.M, ld.K, ld.N, scheme="pattern",
                                   compression=1 / frac, target=target,
                                   executed_frac=frac, x_frac=xf)
                t_base = structured_baseline(ld.M, ld.K, ld.N, 1 / frac,
                                             target)
                choice, t = _pick_precision(
                    choice, t, M=ld.M, K=ld.K, N=ld.N,
                    compression=1 / frac, target=target,
                    executed_frac=frac, x_frac=xf)
            else:
                b, t, t_base = select_block_size(ld.M, ld.K, ld.N,
                                                 compression, beta, target,
                                                 x_frac=xf)
                choice = SchemeChoice("block_punched", block=b)
                choice, t = _pick_precision(
                    choice, t, M=ld.M, K=ld.K, N=ld.N,
                    compression=compression, target=target, x_frac=xf)
        elif ld.kind in ("fc", "conv1x1", "convkxk"):
            xf = im2col_x_frac(ld.taps) if ld.taps > 1 else None
            b, t, t_base = select_block_size(ld.M, ld.K, ld.N, compression,
                                             beta, target, x_frac=xf)
            t_dense = matmul_latency(ld.M, ld.K, ld.N, target=target,
                                     x_frac=xf)
            if t > t_dense:
                # pruning would SLOW this layer (MXU-unfriendly dims, e.g.
                # mamba2's 8512-wide in_proj): map no scheme — latency is
                # the rule method's first-class constraint (§5.2.2)
                choice = SchemeChoice("none")
                t = t_dense
            else:
                choice = SchemeChoice("block", block=b)
                choice, t = _pick_precision(
                    choice, t, M=ld.M, K=ld.K, N=ld.N,
                    compression=compression, target=target, x_frac=xf)
        else:
            raise ValueError(ld.kind)
        spec.append((ld.path, choice))
        report.append({"path": ld.path, "kind": ld.kind,
                       "scheme": choice.scheme, "block": choice.block,
                       "value_dtype": choice.value_dtype,
                       "latency_s": t, "structured_s": t_base,
                       "count": ld.count})
    return spec, report


def total_latency(report) -> float:
    return sum(r["latency_s"] * r["count"] for r in report)
