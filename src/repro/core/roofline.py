"""Roofline analysis over the dry-run artifacts (deliverable g).

Three terms per (arch × shape) on the single-pod 16x16 mesh, from the
extrapolated per-device HLO costs (launch/dryrun.py probe pass):

    compute    = HLO_flops_per_device / peak_FLOPs      (197 TF/s bf16 v5e)
    memory     = HLO_bytes_per_device / HBM_bw          (819 GB/s)
    collective = collective_bytes_per_device / link_bw  (~50 GB/s ICI)

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params,
and the usefulness ratio MODEL_FLOPS / (HLO_flops * n_devices)."""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
N_DEVICES = 256


def param_counts(cfg):
    """(total params, active params) — active discounts MoE experts to
    top_k/n_experts (the 6*N_active*D convention)."""
    from repro.models import transformer as T
    from repro.models.module import path_str
    abs_p = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    total, expert = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(abs_p)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        if "moe/gate" in path_str(path) or "moe/up" in path_str(path) or \
                "moe/down" in path_str(path):
            expert += n
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.top_k / cfg.n_experts
    return total, int(active)


def model_flops(cfg, shape):
    """6*N_active*D for training, 2*N_active*D for inference steps."""
    _, active = param_counts(cfg)
    tokens = shape["batch"] * (shape["seq"] if shape["kind"] != "decode"
                               else 1)
    mult = 6 if shape["kind"] == "train" else 2
    return mult * active * tokens


def analyze(rec, cfg, shape) -> dict:
    ex = rec.get("extrapolated") or {}
    flops = ex.get("flops_remat", ex.get("flops"))
    if not flops:
        return {"error": "no extrapolated costs"}
    if flops <= 0:
        # L2-L1 probe artifact (XLA optimized the two probes differently):
        # fall back to the analytic MODEL_FLOPS per device (footnoted)
        flops = model_flops(cfg, shape) / rec.get("n_devices", N_DEVICES)
    t_compute = flops / PEAK_FLOPS
    t_memory = ex["bytes"] / HBM_BW
    t_coll = ex["coll"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops * rec.get("n_devices", N_DEVICES)
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops over the time the dominant
    # term pins us to, relative to pure-compute peak
    t_model_ideal = mf / (N_DEVICES * PEAK_FLOPS)
    frac = t_model_ideal / bound if bound else 0.0
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": useful, "roofline_fraction": frac,
            "step_time_bound_s": bound}


def load_and_analyze(dryrun_dir) -> list[dict]:
    from repro import configs
    from repro.configs.base import SHAPES
    out = []
    for f in sorted(pathlib.Path(dryrun_dir).glob("*__single.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "OK":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "status": rec.get("status", "?")})
            continue
        cfg = configs.get(rec["arch"])
        row = {"arch": rec["arch"], "shape": rec["shape"], "status": "OK"}
        row.update(analyze(rec, cfg, SHAPES[rec["shape"]]))
        out.append(row)
    return out


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL_FLOPS | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r.get('status','?')[:40]} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['model_flops']:.3g} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(lines)
