"""Interchange formats for sparse execution: PackedLayout and TapLayout.

Every sparse consumer in the repo (``serve.compile.compile_model``,
``kernels.ops``, ``kernels.bsr_matmul``, ``models.layers.linear``, the
batched MoE expert path in ``models.moe`` and the conv paths in
``models.convnet``) produces/consumes one of these two objects instead of
ad-hoc ``{"values", "k_idx"}`` dicts.  Both are registered pytrees, so
layouts live inside param trees, survive ``jax.jit``/``lax.scan`` over
stacked layer axes (leaves may carry leading stack dims; geometry is static
aux data), and new consumers become layout *producers*, not new dict
formats.

``PackedLayout`` (paper §4.3 Fig 4, CSC orientation — see ``core.bcs``) is
the block-sparse format: the dense weight is (K, N); each block COLUMN j
(output tile) stores the list of surviving K-block indices.  With *row
reordering for load balance* (the paper's Fig 4 reorder step), block
columns are sorted by degree and split into ``n_bins`` contiguous bins,
each padded only to its OWN max degree — so the executed column degree
drops toward the mean instead of every column paying the global max.
``perm``/``inv_perm`` carry the (inverse) permutation; the executor gathers
outputs back to original column order (bit-identical results, since
per-column accumulation order is untouched).

``TapLayout`` is the fine-grained sibling for pattern/connectivity-pruned
convolutions (paper §2.1.1 / PatDNN, PCONV): pattern masks carry no block
structure — each (filter, channel) kernel keeps its own 4-of-9 tap set —
so the skippable unit is a single row ("tap") of the im2col band, not a
(bk, bn) block.  ``core.bcs.pattern_lower`` builds it; the Pallas
``kernels.bsr_matmul.tap_gather_conv`` kernel consumes it.  The two layouts
share the same structural conventions (per-bin leaf tuples, degree
sort + binning, perm/inv_perm over the output axis, fused-epilogue bias
helpers), so ``serve.compile`` and the model dispatch treat "packed" as one
concept and pick the executor by layout type.

Quantized values (``core.quant``): either layout may carry its values as
symmetric-scale int8 with an extra per-bin ``scales`` leaf tuple (fp32).
Scale granularity is encoded in the scale shapes (see the dataclass docs);
the kernels dequantize in-kernel before the fp32-accumulated dot, so the
executed result equals the dequantized dense reference.  All-zero groups
store scale 0 (nothing to recover).  ``to_dense`` on a quantized layout
returns the DEQUANTIZED dense weight — the parity oracle for the int8
kernel paths.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


def _dequant(values, scale):
    """Host-side dequantize of one bin: int8 values * fp32 scale, the scale
    right-padded with broadcast axes up to the values rank (so every scale
    granularity — per-block, per-column, per-tap-slot, per-filter —
    broadcasts the same way).  Identity when ``scale`` is None."""
    if scale is None:
        return values
    v = np.asarray(values)
    s = np.asarray(scale, np.float32)
    s = s.reshape(s.shape + (1,) * (v.ndim - s.ndim))
    return v.astype(np.float32) * s


# frozen: ops.pack hands out the SAME cached instance to every caller, so a
# mutable layout would let one consumer corrupt the pack cache for all.
# eq=False: the generated __eq__ would compare jax array leaves (ambiguous
# truth value); identity comparison is the meaningful one for layouts.
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True, eq=False)
class PackedLayout:
    """Uniform-padded BCS/CSC layout, optionally degree-sorted and binned.

    Array leaves (may carry leading stack dims ``...`` = layers / experts):
      values   : tuple of per-bin arrays (..., nb_b, L_b, bk, bn)
      k_idx    : tuple of per-bin arrays (..., nb_b, L_b) int32
      nnz      : (..., Nb) int32 live K-blocks per column, in LAYOUT order
      perm     : (..., Nb) int32 layout position -> original block column,
                 or None when the layout is in original column order
      inv_perm : (..., Nb) int32 original block column -> layout position,
                 or None (identity)
      scales   : None for float values; for int8 values, a tuple of
                 per-bin fp32 arrays — (..., nb_b, L_b) with one symmetric
                 scale per stored block ("block" granularity) or
                 (..., nb_b) with one per block column ("out") — the rank
                 relative to ``values`` encodes the granularity.  All-zero
                 blocks store scale 0.

    Static aux data (hashable; part of the jit cache key):
      block : (bk, bn)
      shape : (K, N) of one dense weight slice
      conv_taps : None for plain GEMM layouts; for im2col-lowered conv
                  layouts, a tuple of (dy, dx, c0) per K-block (built by
                  ``core.bcs.conv_tap_table`` at pack time) — the static
                  offset table ``kernels.bsr_matmul.bsr_conv2d_implicit``
                  uses to gather its x tile straight from the padded
                  feature map instead of a materialized patch tensor.
      n_shards : 0 for a single-device layout.  When S > 0 the layout is
                 tensor-parallel over block COLUMNS: every per-bin leaf
                 carries a shard axis as the LAST stack dim — ``values[b]``
                 is (..., S, nb_b, L_b, bk, bn), ``nnz`` is (..., S, Nb_s)
                 with Nb_s = Nb / S — so layer scans still slice axis 0
                 and the per-layer slice is shard-major for ``jax.vmap`` /
                 ``NamedSharding`` over the mesh "model" axis.  ``perm``
                 becomes (..., S, Nb_s) holding ORIGINAL column ids (the
                 flattened last two axes are a permutation of range(Nb));
                 ``inv_perm`` stays flat (..., Nb) mapping original column
                 -> shard-major layout position, consumed by
                 ``merge_shards``.  Built by ``core.bcs.pack_csc_reordered``
                 with its degree-balanced ``shard_columns`` assignment.

    Padding slots (column degree below the bin max) carry ``k_idx`` 0 and
    all-zero values, so they multiply to nothing; ``nnz`` records the true
    per-column degree for stats and ``to_dense``.
    """

    values: tuple
    k_idx: tuple
    nnz: object
    perm: object = None
    inv_perm: object = None
    block: tuple = (128, 128)
    shape: tuple = (0, 0)
    conv_taps: tuple = None
    scales: tuple = None
    n_shards: int = 0

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        """Flatten into (array leaves, static aux) for jax pytree traversal."""
        children = (self.values, self.k_idx, self.nnz, self.perm,
                    self.inv_perm, self.scales)
        return children, (self.block, self.shape, self.conv_taps,
                          self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild a layout from ``tree_flatten`` output (jax protocol)."""
        values, k_idx, nnz, perm, inv_perm, scales = children
        block, shape, conv_taps, n_shards = aux
        return cls(values=values, k_idx=k_idx, nnz=nnz, perm=perm,
                   inv_perm=inv_perm, block=block, shape=shape,
                   conv_taps=conv_taps, scales=scales, n_shards=n_shards)

    # -- static geometry (no device sync) ------------------------------------

    @property
    def Kb(self) -> int:
        """Number of block rows (K // bk)."""
        return self.shape[0] // self.block[0]

    @property
    def Nb(self) -> int:
        """Number of block columns (N // bn)."""
        return self.shape[1] // self.block[1]

    @property
    def n_bins(self) -> int:
        """Number of degree bins (1 for an unreordered layout)."""
        return len(self.values)

    @property
    def Nb_shard(self) -> int:
        """Block columns per shard (= Nb when unsharded)."""
        return self.Nb // max(1, self.n_shards)

    @property
    def bin_sizes(self) -> tuple:
        """Block columns per bin (per shard on a sharded layout)."""
        return tuple(v.shape[-4] for v in self.values)

    @property
    def bin_degrees(self) -> tuple:
        """Padded column degree L_b of each bin."""
        return tuple(v.shape[-3] for v in self.values)

    @property
    def L_max(self) -> int:
        """Worst padded column degree across bins — what every column would
        pay without reordering/binning."""
        return max(self.bin_degrees)

    @property
    def executed_blocks(self) -> int:
        """Blocks the kernel actually multiplies per dense-weight slice:
        sum over bins of nb_b * L_b (padding included), times the shard
        count on a sharded layout (each shard pads to the cross-shard bin
        max, so per-shard padded work is identical by construction)."""
        per_shard = sum(s * d
                        for s, d in zip(self.bin_sizes, self.bin_degrees))
        return per_shard * max(1, self.n_shards)

    @property
    def L_effective(self) -> float:
        """Mean executed column degree under the binned layout; equals
        ``L_max`` for a single unreordered bin."""
        return self.executed_blocks / max(self.Nb, 1)

    @property
    def flops_saved(self) -> float:
        """Fraction of dense matmul FLOPs the kernel skips.  The padded
        layout executes ``executed_blocks`` of Kb*Nb — NOT the raw block
        density: imbalanced column degrees execute padding blocks."""
        return max(0.0, 1.0 - self.executed_blocks / (self.Kb * self.Nb))

    @property
    def value_dtype(self) -> str:
        """Dtype name of the stored values ("int8" on quantized layouts)."""
        return jnp.asarray(self.values[0]).dtype.name

    def bin_scales(self) -> tuple:
        """Per-bin scale arrays, or a tuple of Nones on float layouts —
        what the packed kernel wrappers zip alongside ``values``."""
        if self.scales is None:
            return (None,) * self.n_bins
        return self.scales

    def shard_index_leaves(self) -> tuple:
        """The per-bin index leaves the kernel launch consumes next to
        ``values`` (``k_idx`` here, ``t_idx`` on TapLayout) — lets
        ``kernels.bsr_matmul._sharded_launch`` drive both layouts."""
        return self.k_idx

    # -- data-dependent stats (host sync; report/test time only) -------------

    @property
    def nnzb(self) -> int:
        """Surviving blocks per dense-weight slice (mean over stack dims)."""
        n = np.asarray(self.nnz)
        # trailing layout axes ((Nb,) or (S, Nb_s)) flatten to Nb either way
        per_slice = n.reshape(-1, self.Nb).sum(axis=1)
        return int(round(float(per_slice.mean())))

    @property
    def shard_balance(self) -> float:
        """max/mean executed blocks per shard were each shard padded to its
        OWN bin maxima — the straggler factor ``core.bcs.shard_columns``
        minimizes.  1.0 on unsharded layouts and under perfect balance."""
        if not self.n_shards:
            return 1.0
        from repro.core import bcs
        return bcs.shard_balance(self.nnz, self.bin_sizes)

    @property
    def density(self) -> float:
        """Surviving-block fraction of the Kb x Nb block grid."""
        return self.nnzb / (self.Kb * self.Nb)

    @property
    def padding_overhead(self) -> float:
        """Executed-block overhead of padding vs ideal CSC."""
        return self.executed_blocks / max(self.nnzb, 1)

    # -- helpers -------------------------------------------------------------

    def unpermute_cols(self, y):
        """Gather a (..., M, N) output from layout column order back to the
        original column order (identity when the layout is unreordered).
        Sharded layouts merge per-shard outputs via ``merge_shards``
        instead (the inverse permutation there spans shards)."""
        assert not self.n_shards, "sharded layouts merge via merge_shards"
        if self.inv_perm is None:
            return y
        bn = self.block[1]
        yb = y.reshape(y.shape[:-1] + (self.Nb, bn))
        yb = jnp.take(yb, self.inv_perm, axis=-2)
        return yb.reshape(y.shape)

    def merge_shards(self, y):
        """Merge shard-local outputs (S, ..., M, N/S) — shard axis LEADING,
        as ``jax.vmap`` over the shard axis produces — into the original
        column order (..., M, N).  The flat ``inv_perm`` already maps each
        original column to its shard-major layout position, so one gather
        is both the cross-shard concat and the un-reorder; under jit with
        sharded operands GSPMD turns it into the all-gather epilogue."""
        assert self.n_shards, "merge_shards needs a sharded layout"
        bn = self.block[1]
        y = jnp.moveaxis(y, 0, -2)                  # (..., M, S, N/S)
        yb = y.reshape(y.shape[:-2] + (self.Nb, bn))
        yb = jnp.take(yb, self.inv_perm, axis=-2)
        return yb.reshape(y.shape[:-2] + (self.Nb * bn,))

    def permute_bias(self, bias):
        """Gather a (N,) bias into layout column order for fused epilogues.
        Returns (N,) on unsharded layouts, (S, N/S) on sharded ones."""
        if bias is None or self.perm is None:
            return bias
        bn = self.block[1]
        bb = bias.reshape(self.Nb, bn)
        pb = jnp.take(bb, self.perm, axis=0)        # (Nb, bn) | (S, Nb_s, bn)
        return pb.reshape(pb.shape[:-2] + (-1,))

    def bin_bias(self, bias):
        """Per-bin (nb_b * bn,) bias slices in layout order (or Nones);
        sharded layouts get (S, nb_b * bn) slices (vmap-ready)."""
        if bias is None:
            return (None,) * self.n_bins
        bn = self.block[1]
        pb = self.permute_bias(bias)
        pb = pb.reshape(pb.shape[:-1] + (-1, bn))   # (Nb, bn) | (S, Nb_s, bn)
        out, start = [], 0
        for s in self.bin_sizes:
            sl = pb[..., start:start + s, :]
            out.append(sl.reshape(sl.shape[:-2] + (s * bn,)))
            start += s
        return tuple(out)

    def to_dense(self):
        """Reconstruct the dense (K, N) weight (single-slice layouts only) —
        the test/debug oracle for round-trip identity.  Quantized layouts
        reconstruct the DEQUANTIZED weight (values * scales), which is what
        the in-kernel dequant path must match."""
        S = max(1, self.n_shards)
        want = 4 + (1 if self.n_shards else 0)
        assert self.values[0].ndim == want, \
            "to_dense needs an unstacked layout"
        K, N = self.shape
        bk, bn = self.block
        Kb, Nb = self.Kb, self.Nb
        dense = np.zeros((Kb, Nb, bk, bn),
                         np.float32 if self.scales is not None
                         else np.asarray(self.values[0]).dtype)
        perm = (np.asarray(self.perm).reshape(S, -1)
                if self.perm is not None
                else np.arange(Nb).reshape(S, -1))
        nnz = np.asarray(self.nnz).reshape(S, -1)
        for sh in range(S):
            col = 0
            for vals, kidx, sc in zip(self.values, self.k_idx,
                                      self.bin_scales()):
                vals = np.asarray(_dequant(vals, sc))
                kidx = np.asarray(kidx)
                if self.n_shards:
                    vals, kidx = vals[sh], kidx[sh]
                for j in range(vals.shape[0]):
                    oj = int(perm[sh, col + j])
                    for l in range(int(nnz[sh, col + j])):
                        dense[int(kidx[j, l]), oj] += vals[j, l]
                col += vals.shape[0]
        return dense.transpose(0, 2, 1, 3).reshape(K, N)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True, eq=False)
class TapLayout:
    """Per-filter tap lists over the im2col band — the pattern-conv layout.

    Built by ``core.bcs.pattern_lower`` from a 4-D pattern/connectivity conv
    mask; consumed by ``kernels.bsr_matmul.tap_gather_conv`` via
    ``kernels.ops.sparse_conv2d_pattern``.  The dense object it represents
    is the im2col-lowered conv weight (K, P) with K = Kh*Kw*Q rows ("taps":
    input channel q at kernel position (i, j)) and P output filters.  Each
    GROUP of ``group`` consecutive filters stores the list of taps any of
    its filters survives at; the kernel gathers exactly those rows of the
    patch matrix and contracts them in one step — pruned taps are never
    multiplied, and rows dead for EVERY filter (``alive`` excludes them)
    are never even materialized in the gathered band.

    Array leaves (single-slice — conv layers are not stacked):
      values   : tuple of per-bin arrays (G_b, L_b, group) — the weight of
                 each filter in the group at tap slot l (zero when that
                 filter prunes the tap, and on padding slots)
      t_idx    : tuple of per-bin arrays (G_b, L_b) int32 — tap slot ->
                 row of the ALIVE band (position in ``alive``, not the full
                 K-row band); padding slots point at row 0 with zero values
      k_full   : tuple of per-bin arrays (G_b, L_b) int32 — tap slot ->
                 row of the FULL im2col band (``alive[t_idx]``, i.e.
                 tap*C + channel), precomputed at pack time; the implicit
                 kernel (``tap_gather_conv_implicit``) decomposes it into
                 (dy, dx, c) input offsets so taps gather straight from the
                 padded feature map.  None on legacy layouts (reconstructed
                 on the fly from ``alive``/``t_idx``).
      nnz      : (G,) int32 true tap-degree per group, in LAYOUT order
      alive    : (R,) int32 rows of the full im2col band live for at least
                 one group — the host-side gather that builds the kernel's
                 input band
      perm     : (G,) int32 layout position -> original filter group, or
                 None when unreordered
      inv_perm : (G,) int32 original filter group -> layout position
      scales   : None for float values; for int8 values, a tuple of
                 per-bin fp32 arrays — (G_b, L_b) with one symmetric scale
                 per tap slot ("block" granularity) or (G_b, 1, group)
                 with one per filter ("out") — the rank encodes the
                 granularity.  All-zero slots store scale 0.

    Static aux data (hashable; part of the jit cache key):
      group : filters per tap-list (1 = exact per-filter taps; larger
              groups widen the output tile but store the tap UNION, which
              erodes savings because patterns differ per kernel)
      shape : (K, P) of the lowered dense weight
      n_shards : 0 for single-device; when S > 0 the filter groups are
                 tensor-parallel exactly like ``PackedLayout`` block
                 columns — per-bin leaves gain a leading shard axis
                 ((S, G_b, L_b, group) values), ``nnz``/``perm`` become
                 (S, G_s), ``inv_perm`` stays flat (G,), and ``alive``
                 stays GLOBAL (every shard gathers the same input band).

    Degree sort + binning mirror ``PackedLayout``: groups are sorted by
    tap-degree and each bin padded to its own max, so connectivity-pruned
    filters (fewer taps) don't pay the densest filter's degree.
    """

    values: tuple
    t_idx: tuple
    nnz: object
    alive: object
    perm: object = None
    inv_perm: object = None
    group: int = 1
    shape: tuple = (0, 0)
    k_full: tuple = None
    scales: tuple = None
    n_shards: int = 0

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        """Flatten into (array leaves, static aux) for jax pytree traversal."""
        children = (self.values, self.t_idx, self.nnz, self.alive,
                    self.perm, self.inv_perm, self.k_full, self.scales)
        return children, (self.group, self.shape, self.n_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild a layout from ``tree_flatten`` output (jax protocol)."""
        values, t_idx, nnz, alive, perm, inv_perm, k_full, scales = children
        group, shape, n_shards = aux
        return cls(values=values, t_idx=t_idx, nnz=nnz, alive=alive,
                   perm=perm, inv_perm=inv_perm, group=group, shape=shape,
                   k_full=k_full, scales=scales, n_shards=n_shards)

    # -- static geometry (no device sync) ------------------------------------

    @property
    def n_groups(self) -> int:
        """Number of filter groups (P // group)."""
        return self.shape[1] // self.group

    @property
    def n_alive(self) -> int:
        """Rows of the im2col band live for at least one group."""
        return self.alive.shape[-1]

    @property
    def n_bins(self) -> int:
        """Number of degree bins (1 for an unreordered layout)."""
        return len(self.values)

    @property
    def bin_sizes(self) -> tuple:
        """Filter groups per bin."""
        return tuple(v.shape[-3] for v in self.values)

    @property
    def bin_degrees(self) -> tuple:
        """Padded tap degree L_b of each bin."""
        return tuple(v.shape[-2] for v in self.values)

    @property
    def L_max(self) -> int:
        """Worst padded tap degree across bins."""
        return max(self.bin_degrees)

    @property
    def n_groups_shard(self) -> int:
        """Filter groups per shard (= n_groups when unsharded)."""
        return self.n_groups // max(1, self.n_shards)

    @property
    def executed_taps(self) -> int:
        """Tap slots the kernel gathers+multiplies (padding included):
        sum over bins of G_b * L_b, times the shard count on a sharded
        layout (bins pad to the cross-shard max, so shards match)."""
        per_shard = sum(s * d
                        for s, d in zip(self.bin_sizes, self.bin_degrees))
        return per_shard * max(1, self.n_shards)

    @property
    def L_effective(self) -> float:
        """Mean executed tap degree under the binned layout."""
        return self.executed_taps / max(self.n_groups, 1)

    @property
    def flops_saved(self) -> float:
        """Fraction of dense conv-GEMM FLOPs the tap-gather kernel skips:
        1 - executed/(K * n_groups), padding included — the executed-tap
        analogue of ``PackedLayout.flops_saved`` (NOT the raw mask
        density)."""
        K = self.shape[0]
        return max(0.0, 1.0 - self.executed_taps / (K * self.n_groups))

    @property
    def value_dtype(self) -> str:
        """Dtype name of the stored values ("int8" on quantized layouts)."""
        return jnp.asarray(self.values[0]).dtype.name

    def bin_scales(self) -> tuple:
        """Per-bin scale arrays, or a tuple of Nones on float layouts —
        what the tap kernel wrappers zip alongside ``values``."""
        if self.scales is None:
            return (None,) * self.n_bins
        return self.scales

    def shard_index_leaves(self) -> tuple:
        """Per-bin index leaves for the generic sharded kernel driver
        (``t_idx`` — see ``PackedLayout.shard_index_leaves``)."""
        return self.t_idx

    # -- data-dependent stats (host sync; report/test time only) -------------

    @property
    def nnz_taps(self) -> int:
        """True surviving tap-list entries (union over each group)."""
        return int(np.asarray(self.nnz).sum())

    @property
    def density(self) -> float:
        """Surviving tap-list fraction of the K x n_groups tap grid."""
        return self.nnz_taps / (self.shape[0] * self.n_groups)

    @property
    def padding_overhead(self) -> float:
        """Executed-tap overhead of bin padding vs exact tap lists."""
        return self.executed_taps / max(self.nnz_taps, 1)

    @property
    def shard_balance(self) -> float:
        """max/mean executed taps per shard were each shard padded to its
        own bin maxima (1.0 on unsharded layouts) — see
        ``PackedLayout.shard_balance``."""
        if not self.n_shards:
            return 1.0
        from repro.core import bcs
        return bcs.shard_balance(self.nnz, self.bin_sizes)

    # -- helpers -------------------------------------------------------------

    def unpermute_cols(self, y):
        """Gather a (..., M, P) output from layout group order back to the
        original filter order (identity when unreordered).  Sharded
        layouts merge per-shard outputs via ``merge_shards`` instead."""
        assert not self.n_shards, "sharded layouts merge via merge_shards"
        if self.inv_perm is None:
            return y
        yb = y.reshape(y.shape[:-1] + (self.n_groups, self.group))
        yb = jnp.take(yb, self.inv_perm, axis=-2)
        return yb.reshape(y.shape)

    def merge_shards(self, y):
        """Merge shard-local outputs (S, ..., M, P/S) — shard axis LEADING —
        into original filter order (..., M, P); one gather through the flat
        ``inv_perm`` is both the concat and the un-reorder (see
        ``PackedLayout.merge_shards``)."""
        assert self.n_shards, "merge_shards needs a sharded layout"
        y = jnp.moveaxis(y, 0, -2)              # (..., M, S, P/S)
        yb = y.reshape(y.shape[:-2] + (self.n_groups, self.group))
        yb = jnp.take(yb, self.inv_perm, axis=-2)
        return yb.reshape(y.shape[:-2] + (self.n_groups * self.group,))

    def permute_bias(self, bias):
        """Gather a (P,) bias into layout group order for fused epilogues.
        Returns (P,) unsharded, (S, P/S) sharded."""
        if bias is None or self.perm is None:
            return bias
        bb = bias.reshape(self.n_groups, self.group)
        pb = jnp.take(bb, self.perm, axis=0)
        return pb.reshape(pb.shape[:-2] + (-1,))

    def bin_bias(self, bias):
        """Per-bin (G_b * group,) bias slices in layout order (or Nones);
        (S, G_b * group) on sharded layouts (vmap-ready)."""
        if bias is None:
            return (None,) * self.n_bins
        pb = self.permute_bias(bias)
        pb = pb.reshape(pb.shape[:-1] + (-1, self.group))
        out, start = [], 0
        for s in self.bin_sizes:
            sl = pb[..., start:start + s, :]
            out.append(sl.reshape(sl.shape[:-2] + (s * self.group,)))
            start += s
        return tuple(out)

    def bin_k_full(self):
        """Per-bin (G_b, L_b) FULL-band row ids (tap*C + channel) for the
        implicit kernel — the precomputed ``k_full`` when present, else
        reconstructed as ``alive[t_idx]`` (trace-safe gather) on legacy
        layouts packed before the aux existed."""
        if self.k_full is not None:
            return self.k_full
        return tuple(jnp.take(self.alive, t, axis=0) for t in self.t_idx)

    def to_dense(self):
        """Reconstruct the dense lowered (K, P) weight — the round-trip
        oracle: must equal ``core.bcs.conv_lower(w * mask)`` (dequantized
        values * scales on a quantized layout)."""
        K, P = self.shape
        S = max(1, self.n_shards)
        dense = np.zeros((K, P),
                         np.float32 if self.scales is not None
                         else np.asarray(self.values[0]).dtype)
        alive = np.asarray(self.alive)
        perm = (np.asarray(self.perm).reshape(S, -1)
                if self.perm is not None
                else np.arange(self.n_groups).reshape(S, -1))
        nnz = np.asarray(self.nnz).reshape(S, -1)
        for sh in range(S):
            col = 0
            for vals, tidx, sc in zip(self.values, self.t_idx,
                                      self.bin_scales()):
                vals = np.asarray(_dequant(vals, sc))
                tidx = np.asarray(tidx)
                if self.n_shards:
                    vals, tidx = vals[sh], tidx[sh]
                for g in range(vals.shape[0]):
                    og = int(perm[sh, col + g])
                    sl = slice(og * self.group, (og + 1) * self.group)
                    for l in range(int(nnz[sh, col + g])):
                        dense[alive[int(tidx[g, l])], sl] += vals[g, l]
                col += vals.shape[0]
        return dense


# Degraded-mode sentinel: installed in place of a layout that failed
# ``core.validate`` so the model dispatch provably CANNOT launch a sparse
# kernel on it (an accidental ``packed is not None`` consumer would crash
# on the missing leaves, not mis-execute).  No array leaves — the whole
# record is static aux, so it hashes into the jit cache key and a
# degrade/un-degrade flip retraces instead of reusing a stale executable.
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DegradedLayer:
    """Marker left behind by ``serve.compile.degrade_invalid_layers`` where
    a packed layout failed validation: the layer executes masked-dense
    (the zeros are baked into its retained dense ``w``) instead of the
    sparse kernel — a slower but never-wrong fallback.

    ``path`` is the layer that degraded, ``code`` the ``LayoutError``
    failure class, ``detail`` the human-readable reason (all strings, all
    static).
    """

    path: str
    code: str
    detail: str

    def tree_flatten(self):
        """No array children — the marker is pure static aux (jax protocol)."""
        return (), (self.path, self.code, self.detail)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild the marker from ``tree_flatten`` output (jax protocol)."""
        del children
        return cls(*aux)
