"""PackedLayout — the single interchange format for block-sparse execution.

Every sparse consumer in the repo (``serve.compile.compile_model``,
``kernels.ops``, ``kernels.bsr_matmul``, ``models.layers.linear`` and the
batched MoE expert path in ``models.moe``) produces/consumes this one object
instead of ad-hoc ``{"values", "k_idx"}`` dicts.  It is a registered pytree,
so layouts live inside param trees, survive ``jax.jit``/``lax.scan`` over
stacked layer axes (leaves may carry leading stack dims; ``block``/``shape``
are static aux data), and new consumers (conv, SSM) become layout
*producers*, not new dict formats.

Layout semantics (paper §4.3 Fig 4, CSC orientation — see ``core.bcs``):
the dense weight is (K, N); each block COLUMN j (output tile) stores the
list of surviving K-block indices.  With *row reordering for load balance*
(the paper's Fig 4 reorder step), block columns are sorted by degree and
split into ``n_bins`` contiguous bins, each padded only to its OWN max
degree — so the executed column degree drops toward the mean instead of
every column paying the global max.  ``perm``/``inv_perm`` carry the
(inverse) permutation; the executor gathers outputs back to original column
order (bit-identical results, since per-column accumulation order is
untouched).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


# frozen: ops.pack hands out the SAME cached instance to every caller, so a
# mutable layout would let one consumer corrupt the pack cache for all.
# eq=False: the generated __eq__ would compare jax array leaves (ambiguous
# truth value); identity comparison is the meaningful one for layouts.
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True, eq=False)
class PackedLayout:
    """Uniform-padded BCS/CSC layout, optionally degree-sorted and binned.

    Array leaves (may carry leading stack dims ``...`` = layers / experts):
      values   : tuple of per-bin arrays (..., nb_b, L_b, bk, bn)
      k_idx    : tuple of per-bin arrays (..., nb_b, L_b) int32
      nnz      : (..., Nb) int32 live K-blocks per column, in LAYOUT order
      perm     : (..., Nb) int32 layout position -> original block column,
                 or None when the layout is in original column order
      inv_perm : (..., Nb) int32 original block column -> layout position,
                 or None (identity)

    Static aux data (hashable; part of the jit cache key):
      block : (bk, bn)
      shape : (K, N) of one dense weight slice
    """

    values: tuple
    k_idx: tuple
    nnz: object
    perm: object = None
    inv_perm: object = None
    block: tuple = (128, 128)
    shape: tuple = (0, 0)

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        children = (self.values, self.k_idx, self.nnz, self.perm,
                    self.inv_perm)
        return children, (self.block, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, k_idx, nnz, perm, inv_perm = children
        block, shape = aux
        return cls(values=values, k_idx=k_idx, nnz=nnz, perm=perm,
                   inv_perm=inv_perm, block=block, shape=shape)

    # -- static geometry (no device sync) ------------------------------------

    @property
    def Kb(self) -> int:
        return self.shape[0] // self.block[0]

    @property
    def Nb(self) -> int:
        return self.shape[1] // self.block[1]

    @property
    def n_bins(self) -> int:
        return len(self.values)

    @property
    def bin_sizes(self) -> tuple:
        """Block columns per bin."""
        return tuple(v.shape[-4] for v in self.values)

    @property
    def bin_degrees(self) -> tuple:
        """Padded column degree L_b of each bin."""
        return tuple(v.shape[-3] for v in self.values)

    @property
    def L_max(self) -> int:
        """Worst padded column degree across bins — what every column would
        pay without reordering/binning."""
        return max(self.bin_degrees)

    @property
    def executed_blocks(self) -> int:
        """Blocks the kernel actually multiplies per dense-weight slice:
        sum over bins of nb_b * L_b (padding included)."""
        return sum(s * d for s, d in zip(self.bin_sizes, self.bin_degrees))

    @property
    def L_effective(self) -> float:
        """Mean executed column degree under the binned layout; equals
        ``L_max`` for a single unreordered bin."""
        return self.executed_blocks / max(self.Nb, 1)

    @property
    def flops_saved(self) -> float:
        """Fraction of dense matmul FLOPs the kernel skips.  The padded
        layout executes ``executed_blocks`` of Kb*Nb — NOT the raw block
        density: imbalanced column degrees execute padding blocks."""
        return max(0.0, 1.0 - self.executed_blocks / (self.Kb * self.Nb))

    # -- data-dependent stats (host sync; report/test time only) -------------

    @property
    def nnzb(self) -> int:
        """Surviving blocks per dense-weight slice (mean over stack dims)."""
        n = np.asarray(self.nnz)
        per_slice = n.reshape(-1, n.shape[-1]).sum(axis=1)
        return int(round(float(per_slice.mean())))

    @property
    def density(self) -> float:
        return self.nnzb / (self.Kb * self.Nb)

    @property
    def padding_overhead(self) -> float:
        """Executed-block overhead of padding vs ideal CSC."""
        return self.executed_blocks / max(self.nnzb, 1)

    # -- helpers -------------------------------------------------------------

    def unpermute_cols(self, y):
        """Gather a (..., M, N) output from layout column order back to the
        original column order (identity when the layout is unreordered)."""
        if self.inv_perm is None:
            return y
        bn = self.block[1]
        yb = y.reshape(y.shape[:-1] + (self.Nb, bn))
        yb = jnp.take(yb, self.inv_perm, axis=-2)
        return yb.reshape(y.shape)

    def permute_bias(self, bias):
        """Gather a (N,) bias into layout column order for fused epilogues."""
        if bias is None or self.perm is None:
            return bias
        bn = self.block[1]
        bb = bias.reshape(self.Nb, bn)
        return jnp.take(bb, self.perm, axis=0).reshape(-1)

    def bin_bias(self, bias):
        """Per-bin (nb_b * bn,) bias slices in layout order (or Nones)."""
        if bias is None:
            return (None,) * self.n_bins
        bn = self.block[1]
        pb = self.permute_bias(bias).reshape(self.Nb, bn)
        out, start = [], 0
        for s in self.bin_sizes:
            out.append(pb[start:start + s].reshape(-1))
            start += s
        return tuple(out)

    def to_dense(self):
        """Reconstruct the dense (K, N) weight (single-slice layouts only) —
        the test/debug oracle for round-trip identity."""
        assert self.values[0].ndim == 4, "to_dense needs an unstacked layout"
        K, N = self.shape
        bk, bn = self.block
        Kb, Nb = self.Kb, self.Nb
        dense = np.zeros((Kb, Nb, bk, bn),
                         np.asarray(self.values[0]).dtype)
        col = 0
        perm = (np.asarray(self.perm) if self.perm is not None
                else np.arange(Nb))
        nnz = np.asarray(self.nnz)
        for vals, kidx in zip(self.values, self.k_idx):
            vals, kidx = np.asarray(vals), np.asarray(kidx)
            for j in range(vals.shape[0]):
                oj = int(perm[col + j])
                for l in range(int(nnz[col + j])):
                    dense[int(kidx[j, l]), oj] += vals[j, l]
            col += vals.shape[0]
        return dense.transpose(0, 2, 1, 3).reshape(K, N)
