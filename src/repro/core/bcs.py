"""Blocked Compressed Storage (BCS, paper §4.3 Fig 4) — TPU adaptation.

Faithful pieces: CSR-of-blocks with hierarchical column-index compression
(identical per-row block-column patterns are stored once; the *occurrence*
array maps rows to patterns) and row reordering for load balance.

TPU adaptation (DESIGN.md §2): the unit the executor can skip is a whole
(bk×bn) weight block (the MXU-tile analogue of PatDNN's generated code
skipping pruned weights).  Fine-grained intra-block row/col sparsity from
block-based pruning rides along inside surviving blocks (accuracy win);
fully-zero blocks are skipped by the Pallas kernel (compute/HBM win).  The
kernel consumes the *uniform padded* layout from ``pad_to_uniform`` — equal
trip counts per grid row = the thread-load-balance analogue."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp


@dataclass
class BCS:
    shape: tuple            # dense (K, N)
    block: tuple            # (bk, bn)
    values: np.ndarray      # (nnzb, bk, bn) surviving blocks, row-major
    col_idx: np.ndarray     # (nnzb,) block-column index of each block
    row_ptr: np.ndarray     # (Kb+1,) CSR row pointers over block rows
    # hierarchical column compression (Fig 4): unique column patterns +
    # occurrence mapping row -> pattern id
    patterns: list          # list of np arrays (col indices per unique row)
    occurrence: np.ndarray  # (Kb,) pattern id per block row

    @property
    def nnzb(self):
        return len(self.col_idx)

    @property
    def density(self):
        Kb = self.shape[0] // self.block[0]
        Nb = self.shape[1] // self.block[1]
        return self.nnzb / (Kb * Nb)

    def index_bytes(self) -> int:
        """Metadata bytes under hierarchical compression vs plain CSR."""
        pat = sum(len(p) for p in self.patterns)
        return 4 * (pat + len(self.occurrence) + len(self.row_ptr))

    def csr_index_bytes(self) -> int:
        return 4 * (len(self.col_idx) + len(self.row_ptr))


def from_dense(w, mask, block) -> BCS:
    """Pack the masked weight into BCS.  A block is stored iff any weight in
    it survives; stored blocks keep their interior zeros (fine-grained
    sparsity inside the MXU tile)."""
    w = np.asarray(w * mask.astype(w.dtype))
    K, N = w.shape
    bk, bn = block
    assert K % bk == 0 and N % bn == 0
    Kb, Nb = K // bk, N // bn
    mblk = np.asarray(mask).reshape(Kb, bk, Nb, bn).transpose(0, 2, 1, 3)
    alive = mblk.reshape(Kb, Nb, -1).any(axis=-1)            # (Kb, Nb)
    wblk = w.reshape(Kb, bk, Nb, bn).transpose(0, 2, 1, 3)

    values, col_idx, row_ptr = [], [], [0]
    patterns, pat_lookup, occurrence = [], {}, []
    for i in range(Kb):
        cols = np.nonzero(alive[i])[0]
        for j in cols:
            values.append(wblk[i, j])
            col_idx.append(j)
        row_ptr.append(len(col_idx))
        key = tuple(cols.tolist())
        if key not in pat_lookup:
            pat_lookup[key] = len(patterns)
            patterns.append(cols)
        occurrence.append(pat_lookup[key])
    values = np.stack(values) if values else np.zeros((0, bk, bn), w.dtype)
    return BCS(shape=(K, N), block=block, values=values,
               col_idx=np.asarray(col_idx, np.int32),
               row_ptr=np.asarray(row_ptr, np.int32),
               patterns=patterns,
               occurrence=np.asarray(occurrence, np.int32))


def to_dense(bcs: BCS) -> np.ndarray:
    K, N = bcs.shape
    bk, bn = bcs.block
    out = np.zeros((K // bk, N // bn, bk, bn), bcs.values.dtype)
    for i in range(K // bk):
        for k in range(bcs.row_ptr[i], bcs.row_ptr[i + 1]):
            out[i, bcs.col_idx[k]] = bcs.values[k]
    return out.transpose(0, 2, 1, 3).reshape(K, N)


def pad_to_uniform(bcs: BCS):
    """Uniform per-row layout for the Pallas kernel: every block row gets
    ``Lmax`` slots (pad with zero blocks pointing at column 0) — the static
    Pallas grid needs equal trip counts; padding blocks multiply by zero.

    Returns (values (Kb, Lmax, bk, bn), col_idx (Kb, Lmax) int32, nnz (Kb,)).
    """
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb = K // bk
    nnz = np.diff(bcs.row_ptr)
    Lmax = max(1, int(nnz.max()) if len(nnz) else 1)
    vals = np.zeros((Kb, Lmax, bk, bn), bcs.values.dtype)
    cols = np.zeros((Kb, Lmax), np.int32)
    for i in range(Kb):
        s, e = bcs.row_ptr[i], bcs.row_ptr[i + 1]
        vals[i, :e - s] = bcs.values[s:e]
        cols[i, :e - s] = bcs.col_idx[s:e]
    return jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(nnz, jnp.int32)


def pad_to_uniform_csc(bcs: BCS):
    """Column-major uniform layout — what the Pallas kernel consumes.

    For each block COLUMN j (output tile), the list of surviving K-block
    indices, zero-padded to the max column degree ``Lmax`` (load-balanced
    static grid).  Returns (values (Nb, Lmax, bk, bn), k_idx (Nb, Lmax)
    int32, nnz (Nb,)).  Padding slots point at k-block 0 with zero values —
    they contribute nothing."""
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb, Nb = K // bk, N // bn
    cols = [[] for _ in range(Nb)]
    for i in range(Kb):
        for t in range(bcs.row_ptr[i], bcs.row_ptr[i + 1]):
            cols[bcs.col_idx[t]].append((i, t))
    nnz = np.asarray([len(c) for c in cols], np.int32)
    Lmax = max(1, int(nnz.max()) if len(nnz) else 1)
    vals = np.zeros((Nb, Lmax, bk, bn), bcs.values.dtype)
    kidx = np.zeros((Nb, Lmax), np.int32)
    for j in range(Nb):
        for l, (i, t) in enumerate(cols[j]):
            vals[j, l] = bcs.values[t]
            kidx[j, l] = i
    return jnp.asarray(vals), jnp.asarray(kidx), jnp.asarray(nnz)


def load_imbalance(bcs: BCS) -> float:
    """max/mean surviving blocks per row — what row-binning equalizes."""
    nnz = np.diff(bcs.row_ptr).astype(np.float64)
    if nnz.mean() == 0:
        return 1.0
    return float(nnz.max() / max(nnz.mean(), 1e-9))
