"""Blocked Compressed Storage (BCS, paper §4.3 Fig 4) — TPU adaptation.

Faithful pieces: CSR-of-blocks with hierarchical column-index compression
(identical per-row block-column patterns are stored once; the *occurrence*
array maps rows to patterns) and row reordering for load balance.

TPU adaptation (DESIGN.md §2): the unit the executor can skip is a whole
(bk×bn) weight block (the MXU-tile analogue of PatDNN's generated code
skipping pruned weights).  Fine-grained intra-block row/col sparsity from
block-based pruning rides along inside surviving blocks (accuracy win);
fully-zero blocks are skipped by the Pallas kernel (compute/HBM win).  The
kernel consumes the *uniform padded* layout from ``pad_to_uniform`` — equal
trip counts per grid row = the thread-load-balance analogue.

Packing is fully vectorized (argsort/cumsum CSC construction) so whole-model
compiles stay off the Python-loop floor; the ``*_loop`` reference
implementations are kept for equivalence tests and the packing benchmark.

Downstream interchange format: everything the executor consumes is a
``core.packed.PackedLayout`` (built here by ``pack_csc_reordered`` or
assembled from ``pack_csc`` by ``kernels.ops.pack``) — the single layout
object shared by ``serve.compile``, ``kernels.ops``/``bsr_matmul``, and
``models.layers``/``models.moe``.  Row reordering for load balance (Fig 4)
lives in ``pack_csc_reordered``: block columns sorted by degree and binned
so the padded column degree L drops toward the mean instead of the max."""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp


@dataclass
class BCS:
    shape: tuple            # dense (K, N)
    block: tuple            # (bk, bn)
    values: np.ndarray      # (nnzb, bk, bn) surviving blocks, row-major
    col_idx: np.ndarray     # (nnzb,) block-column index of each block
    row_ptr: np.ndarray     # (Kb+1,) CSR row pointers over block rows
    # hierarchical column compression (Fig 4): unique column patterns +
    # occurrence mapping row -> pattern id
    patterns: list          # list of np arrays (col indices per unique row)
    occurrence: np.ndarray  # (Kb,) pattern id per block row

    @property
    def nnzb(self):
        return len(self.col_idx)

    @property
    def density(self):
        Kb = self.shape[0] // self.block[0]
        Nb = self.shape[1] // self.block[1]
        return self.nnzb / (Kb * Nb)

    def index_bytes(self) -> int:
        """Metadata bytes under hierarchical compression vs plain CSR."""
        pat = sum(len(p) for p in self.patterns)
        return 4 * (pat + len(self.occurrence) + len(self.row_ptr))

    def csr_index_bytes(self) -> int:
        return 4 * (len(self.col_idx) + len(self.row_ptr))


def _blockify(w, mask, block):
    """Shared prologue: (Kb, Nb, bk, bn) weight blocks + (Kb, Nb) liveness.
    ``wblk`` is a transposed VIEW (no 2·K·N copy); ``any`` reduces over the
    tuple axis directly instead of materializing a transposed block tensor."""
    mask = np.asarray(mask)
    w = np.asarray(w)
    w = w * mask.astype(w.dtype, copy=False)
    K, N = w.shape
    bk, bn = block
    assert K % bk == 0 and N % bn == 0
    Kb, Nb = K // bk, N // bn
    # two matmul reductions (BLAS) beat one strided any(axis=(1, 3)); the
    # abs keeps "any nonzero" exact under float summation
    am = np.abs(np.asarray(mask, np.float32))
    ones_k = np.ones(bk, np.float32)
    ones_n = np.ones(bn, np.float32)
    s1 = am.reshape(Kb, bk, N).transpose(0, 2, 1) @ ones_k   # (Kb, N)
    alive = (s1.reshape(Kb * Nb, bn) @ ones_n).reshape(Kb, Nb) > 0
    wblk = w.reshape(Kb, bk, Nb, bn).transpose(0, 2, 1, 3)
    return w, wblk, alive, (K, N, bk, bn, Kb, Nb)


def from_dense(w, mask, block) -> BCS:
    """Pack the masked weight into BCS.  A block is stored iff any weight in
    it survives; stored blocks keep their interior zeros (fine-grained
    sparsity inside the MXU tile).  Vectorized: one ``nonzero`` + ``bincount``
    replaces the per-(row, col) Python loop."""
    w, wblk, alive, (K, N, bk, bn, Kb, Nb) = _blockify(w, mask, block)

    rows, cols = np.nonzero(alive)           # row-major = CSR block order
    values = wblk[rows, cols] if len(rows) else np.zeros((0, bk, bn), w.dtype)
    row_ptr = np.zeros(Kb + 1, np.int64)
    np.cumsum(np.bincount(rows, minlength=Kb), out=row_ptr[1:])

    # hierarchical column compression: dedupe identical per-row liveness
    # patterns in first-occurrence order.  Keyed on packed row bytes — the
    # dict loop is O(Kb) rows, not O(Kb·Nb) blocks.
    packed = np.packbits(alive, axis=1)
    rb, stride = packed.tobytes(), packed.shape[1]
    patterns, lookup = [], {}
    occurrence = np.empty(Kb, np.int32)
    for i in range(Kb):
        key = rb[i * stride:(i + 1) * stride]
        pid = lookup.get(key)
        if pid is None:
            pid = lookup[key] = len(patterns)
            patterns.append(np.nonzero(alive[i])[0])
        occurrence[i] = pid
    return BCS(shape=(K, N), block=block, values=values,
               col_idx=cols.astype(np.int32),
               row_ptr=row_ptr.astype(np.int32),
               patterns=patterns,
               occurrence=occurrence)


def from_dense_loop(w, mask, block) -> BCS:
    """Pure-Python reference packer (the original O(Kb·Nb) implementation).
    Kept for bit-identity tests and the packing speed benchmark."""
    w, wblk, alive, (K, N, bk, bn, Kb, Nb) = _blockify(w, mask, block)

    values, col_idx, row_ptr = [], [], [0]
    patterns, pat_lookup, occurrence = [], {}, []
    for i in range(Kb):
        cols = np.nonzero(alive[i])[0]
        for j in cols:
            values.append(wblk[i, j])
            col_idx.append(j)
        row_ptr.append(len(col_idx))
        key = tuple(cols.tolist())
        if key not in pat_lookup:
            pat_lookup[key] = len(patterns)
            patterns.append(cols)
        occurrence.append(pat_lookup[key])
    values = np.stack(values) if values else np.zeros((0, bk, bn), w.dtype)
    return BCS(shape=(K, N), block=block, values=values,
               col_idx=np.asarray(col_idx, np.int32),
               row_ptr=np.asarray(row_ptr, np.int32),
               patterns=patterns,
               occurrence=np.asarray(occurrence, np.int32))


def to_dense(bcs: BCS) -> np.ndarray:
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb, Nb = K // bk, N // bn
    out = np.zeros((Kb, Nb, bk, bn), bcs.values.dtype)
    rows = np.repeat(np.arange(Kb), np.diff(bcs.row_ptr))
    out[rows, bcs.col_idx] = bcs.values
    return out.transpose(0, 2, 1, 3).reshape(K, N)


def pad_to_uniform(bcs: BCS):
    """Uniform per-row layout for the Pallas kernel: every block row gets
    ``Lmax`` slots (pad with zero blocks pointing at column 0) — the static
    Pallas grid needs equal trip counts; padding blocks multiply by zero.

    Returns (values (Kb, Lmax, bk, bn), col_idx (Kb, Lmax) int32, nnz (Kb,)).
    """
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb = K // bk
    nnz = np.diff(bcs.row_ptr)
    Lmax = max(1, int(nnz.max()) if len(nnz) else 1)
    vals = np.zeros((Kb, Lmax, bk, bn), bcs.values.dtype)
    cols = np.zeros((Kb, Lmax), np.int32)
    rows = np.repeat(np.arange(Kb), nnz)
    slot = np.arange(bcs.nnzb) - np.repeat(bcs.row_ptr[:-1], nnz)
    vals[rows, slot] = bcs.values
    cols[rows, slot] = bcs.col_idx
    return jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(nnz, jnp.int32)


def pad_to_uniform_csc(bcs: BCS):
    """Column-major uniform layout — what the Pallas kernel consumes.

    For each block COLUMN j (output tile), the list of surviving K-block
    indices, zero-padded to the max column degree ``Lmax`` (load-balanced
    static grid).  Returns (values (Nb, Lmax, bk, bn), k_idx (Nb, Lmax)
    int32, nnz (Nb,)).  Padding slots point at k-block 0 with zero values —
    they contribute nothing.

    Vectorized CSC construction: a stable argsort over ``col_idx`` groups
    blocks by column while preserving row order; cumsum'd per-column counts
    give each block's destination slot, and a single scatter through the
    composed permutation places every block — no Python per-block loop and
    no intermediate permuted copy of ``values``.  (The serve path uses the
    even faster ``pack_csc`` below; this stays as the BCS-object route.)"""
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb, Nb = K // bk, N // bn
    t_order = np.argsort(bcs.col_idx, kind="stable")
    cnt = np.bincount(bcs.col_idx, minlength=Nb)
    nnz = cnt.astype(np.int32)
    Lmax = max(1, int(cnt.max()) if len(cnt) else 1)
    col_ptr = np.zeros(Nb + 1, np.int64)
    np.cumsum(cnt, out=col_ptr[1:])
    row_of = np.repeat(np.arange(Kb), np.diff(bcs.row_ptr))  # block row per t
    vals = np.zeros((Nb, Lmax, bk, bn), bcs.values.dtype)
    kidx = np.zeros((Nb, Lmax), np.int32)
    slot = np.arange(bcs.nnzb) - np.repeat(col_ptr[:-1], cnt)
    dest = np.empty(bcs.nnzb, np.int64)                      # flat CSC slot
    dest[t_order] = bcs.col_idx[t_order].astype(np.int64) * Lmax + slot
    vals.reshape(Nb * Lmax, bk, bn)[dest] = bcs.values
    kidx.reshape(-1)[dest] = row_of
    return jnp.asarray(vals), jnp.asarray(kidx), jnp.asarray(nnz)


@functools.partial(jax.jit, static_argnames=("kb", "bk", "nb", "bn"))
def _alive_t(mask, *, kb, bk, nb, bn):
    """(K, N) mask -> (Nb, Kb) bool block-liveness, transposed (CSC order)."""
    am = jnp.abs(mask.astype(jnp.float32))
    return jnp.transpose(am.reshape(kb, bk, nb, bn).sum(axis=(1, 3))) > 0


@functools.partial(jax.jit, static_argnames=("kb", "bk", "nb", "bn"))
def _csc_move(w, mask, src_idx, *, kb, bk, nb, bn):
    """All heavy data movement of packing in one XLA program: mask multiply,
    transpose to column-major block order, and ONE gather that places every
    output slot — slot (j, l) reads its live block, padding slots read the
    appended all-zero block.  Gather-only on purpose: XLA scatters of many
    tiny blocks are an order of magnitude slower than the equivalent
    gather, and the (Nb, Kb) destination scatter lives on host as a cheap
    int32 index fill instead.  Multithreaded on CPU, fused on accelerator."""
    wm = w * mask.astype(w.dtype)
    wcsc = jnp.transpose(wm.reshape(kb, bk, nb, bn),
                         (2, 0, 1, 3)).reshape(nb * kb, bk, bn)
    wcsc = jnp.concatenate([wcsc, jnp.zeros((1, bk, bn), w.dtype)])
    return wcsc[src_idx]                     # (nb, kb, bk, bn)


def pack_csc(w, mask, block):
    """Fused ``from_dense`` + ``pad_to_uniform_csc`` without the BCS (CSR)
    intermediate — the serve-path packer behind ``kernels.ops.pack``.

    Going through CSR costs a transpose-like permutation of all block
    payloads (row-major extract, column-major scatter) at (bk·bn)-element
    granularity — cache-hostile for small blocks, and single-threaded in
    numpy.  Here only the O(Kb·Nb) index bookkeeping stays on host; the
    O(K·N) block movement runs as one jitted gather-only XLA program
    (``_csc_move``) whose (Nb, Kb) slot->source map is filled on host, so
    the compiled program depends only on (shape, block) — not on the mask —
    and a final cheap device slice trims the padded column degree to Lmax.

    Returns (values (Nb, Lmax, bk, bn), k_idx (Nb, Lmax) int32, nnz (Nb,),
    density) — bit-identical to from_dense -> pad_to_uniform_csc."""
    w = jnp.asarray(w)
    mask = jnp.asarray(mask)
    K, N = w.shape
    bk, bn = block
    assert K % bk == 0 and N % bn == 0
    Kb, Nb = K // bk, N // bn
    dims = dict(kb=Kb, bk=bk, nb=Nb, bn=bn)
    alive_t = np.asarray(_alive_t(mask, **dims))             # (Nb, Kb)
    cnt = alive_t.sum(axis=1)
    nnz = cnt.astype(np.int32)
    nnzb = int(cnt.sum())
    Lmax = max(1, int(cnt.max()) if cnt.size else 1)
    cols_j, rows_j = np.nonzero(alive_t)     # CSC order: by col, then row
    col_ptr = np.zeros(Nb + 1, np.int64)
    np.cumsum(cnt, out=col_ptr[1:])
    slot = np.arange(nnzb) - np.repeat(col_ptr[:-1], cnt)
    # slot -> source block map; unfilled slots read the appended zero block
    src = np.full(Nb * Kb, Nb * Kb, np.int32)
    src[cols_j * Kb + slot] = cols_j * Kb + rows_j
    vals = _csc_move(w, mask, jnp.asarray(src.reshape(Nb, Kb)), **dims)
    if Lmax < Kb:
        vals = vals[:, :Lmax]                                # device slice
    kidx = np.zeros((Nb, Lmax), np.int32)
    kidx.reshape(-1)[cols_j * Lmax + slot] = rows_j
    density = nnzb / (Kb * Nb)
    return vals, jnp.asarray(kidx), jnp.asarray(nnz), density


def bin_bounds(nb: int, n_bins: int) -> tuple:
    """Contiguous (start, end) ranges splitting ``nb`` sorted block columns
    into ``n_bins`` near-equal bins.  Depends only on (nb, n_bins), so every
    slice of a stacked layer/expert axis gets identical bin sizes — the
    stacking invariant ``serve.compile._pack_stacked`` relies on."""
    n_bins = max(1, min(n_bins, nb))
    edges = np.linspace(0, nb, n_bins + 1).round().astype(int)
    return tuple((int(a), int(b)) for a, b in zip(edges[:-1], edges[1:])
                 if b > a)


def shard_columns(cnt, n_shards):
    """Degree-balanced assignment of block columns to tensor-parallel shards.

    Greedy LPT bin-packing with an exact per-shard capacity: columns are
    visited in descending-degree order and each goes to the least-loaded
    shard that still has room, so every shard ends up with exactly
    ``Nb / n_shards`` columns (the equal-width invariant stacking and
    ``NamedSharding`` both need) while per-shard total degree — the work a
    device actually executes — is equalized.  This is the cross-DEVICE
    analogue of the paper's Fig 4 row reordering: there, degree bins keep
    one heavy column from inflating every column's padding; here, the same
    degree statistics keep one heavy *shard* from making every other
    device wait on the straggler.

    Returns an ``(n_shards, Nb // n_shards)`` int32 array of ORIGINAL
    column indices; each shard's row is in descending-degree order (the
    order per-shard binning expects).  Requires ``n_shards`` | ``Nb``.
    """
    cnt = np.asarray(cnt)
    Nb = cnt.shape[0]
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if Nb % n_shards:
        raise ValueError(
            f"n_shards={n_shards} does not divide Nb={Nb} block columns")
    cap = Nb // n_shards
    order = np.argsort(-cnt, kind="stable")
    load = np.zeros(n_shards, np.int64)
    fill = np.zeros(n_shards, np.int64)
    out = np.empty((n_shards, cap), np.int32)
    for j in order:
        open_ = fill < cap
        s = int(np.flatnonzero(open_)[np.argmin(load[open_])])
        out[s, fill[s]] = j
        fill[s] += 1
        load[s] += cnt[j]
    return out


def shard_balance(nnz, bin_sizes) -> float:
    """max/mean executed blocks per shard, each shard padded independently.

    ``nnz`` is the layout-order degree array ``(..., S, Nb_s)`` and
    ``bin_sizes`` the per-bin column counts.  The stacked layout pads every
    bin to the cross-shard max degree, so its *padded* work is equal by
    construction; what this measures is the straggler factor if each shard
    ran its own best-case layout (bins padded to that shard's own max) —
    i.e. how well ``shard_columns`` equalized the real work.  1.0 = perfect.
    """
    n = np.asarray(nnz)
    if n.ndim < 2:
        return 1.0
    flat = n.reshape(-1, n.shape[-2], n.shape[-1])   # (slices, S, Nb_s)
    per_shard = np.zeros(flat.shape[:2], np.float64)  # executed blocks
    start = 0
    for sz in bin_sizes:
        seg = flat[..., start:start + sz]
        per_shard += sz * np.maximum(seg.max(axis=-1), 1)
        start += sz
    mean = per_shard.mean(axis=-1)
    ratio = per_shard.max(axis=-1) / np.maximum(mean, 1e-9)
    return float(ratio.max())


def pack_csc_reordered(w, mask, block, n_bins=4, n_shards=0):
    """Degree-sorted, binned CSC packing — the paper's Fig 4 *row reordering
    for load balance*, applied to the kernel's work rows (block columns).

    ``pack_csc`` pads every block column to the global max degree L, so one
    heavy column makes the whole matrix execute L·Nb blocks.  Here columns
    are sorted by descending degree and split into ``n_bins`` contiguous
    bins, each padded only to its own max — heavy columns share a deep bin,
    light columns a shallow one, and the executed degree drops toward the
    mean.  Within a column the K-block order is untouched, so per-output
    accumulation order (and therefore the result) is bit-identical to the
    unreordered kernel; outputs just need a final column gather.

    Returns a ``core.packed.PackedLayout`` with per-bin values/k_idx,
    ``perm`` (layout position -> original column) and ``inv_perm``.

    ``n_shards > 0`` produces the tensor-parallel variant: columns are
    first distributed across shards by ``shard_columns`` (degree-balanced,
    exactly ``Nb / n_shards`` per shard), then each shard is degree-sorted
    and binned exactly as above, with every bin padded to the CROSS-shard
    max degree so the per-bin leaves stack into one array with a leading
    shard axis — ``values[b]`` is ``(S, nb_b, L_b, bk, bn)``, ``perm`` is
    ``(S, Nb_s)`` holding ORIGINAL column ids, and ``inv_perm`` stays a
    flat ``(Nb,)`` map original column -> shard-major layout position.
    Per-column accumulation order is still untouched, so sharded outputs
    merge to the bit-identical unsharded result.
    """
    from repro.core.packed import PackedLayout

    vals, kidx, nnz, density = pack_csc(w, mask, block)
    cnt = np.asarray(nnz)
    Nb = cnt.shape[0]
    if n_shards:
        assign = shard_columns(cnt, n_shards)          # (S, Nb_s)
        S, Nbs = assign.shape
        inv = np.empty(Nb, np.int32)
        inv[assign.reshape(-1)] = np.arange(Nb, dtype=np.int32)
        vs = jnp.take(vals, jnp.asarray(assign.reshape(-1)), axis=0)
        ks = jnp.take(kidx, jnp.asarray(assign.reshape(-1)), axis=0)
        vs = vs.reshape((S, Nbs) + vs.shape[1:])
        ks = ks.reshape((S, Nbs) + ks.shape[1:])
        cnt_sh = cnt[assign]                           # (S, Nb_s)
        bin_values, bin_kidx = [], []
        for s, e in bin_bounds(Nbs, n_bins):
            Lb = max(1, int(cnt_sh[:, s:e].max()))     # cross-shard max
            bin_values.append(vs[:, s:e, :Lb])
            bin_kidx.append(ks[:, s:e, :Lb])
        return PackedLayout(values=tuple(bin_values), k_idx=tuple(bin_kidx),
                            nnz=jnp.asarray(cnt_sh),
                            perm=jnp.asarray(assign),
                            inv_perm=jnp.asarray(inv),
                            block=tuple(block), shape=tuple(np.shape(w)),
                            n_shards=S)
    order = np.argsort(-cnt, kind="stable").astype(np.int32)
    inv = np.empty(Nb, np.int32)
    inv[order] = np.arange(Nb, dtype=np.int32)
    vs = jnp.take(vals, jnp.asarray(order), axis=0)
    ks = jnp.take(kidx, jnp.asarray(order), axis=0)
    cnt_sorted = cnt[order]
    bin_values, bin_kidx = [], []
    for s, e in bin_bounds(Nb, n_bins):
        Lb = max(1, int(cnt_sorted[s:e].max()))
        bin_values.append(vs[s:e, :Lb])
        bin_kidx.append(ks[s:e, :Lb])
    return PackedLayout(values=tuple(bin_values), k_idx=tuple(bin_kidx),
                        nnz=jnp.asarray(cnt_sorted),
                        perm=jnp.asarray(order), inv_perm=jnp.asarray(inv),
                        block=tuple(block), shape=tuple(np.shape(w)))


def conv_lower(w):
    """Im2col lowering of a conv weight: (P, Q, Kh, Kw) -> (Kh*Kw*Q, P).

    Row order is (kh, kw, q) — tap-major, channel-minor — matching the patch
    extraction in ``kernels.ops.sparse_conv2d``, so ``patches @ lowered`` is
    exactly the convolution.  Works on masks too (same shape convention).

    Why this orientation makes block-punched masks BCS-skippable: a punched
    group (paper §4.1.2, kernel block (bp, bq), position (m, n)) zeroes all
    bq consecutive channels q of the (m, n) band times bp consecutive
    filters p — a contiguous (bq, bp) zero tile of the lowered GEMM, i.e. a
    whole dead block under packing block (bk, bn) = (bq, bp) whenever
    Q % bq == 0 (bands are length Q, so bq-blocks never straddle taps)."""
    w = np.asarray(w)
    P, Q, Kh, Kw = w.shape
    return np.ascontiguousarray(
        w.transpose(2, 3, 1, 0).reshape(Kh * Kw * Q, P))


def pattern_lower(w, mask, *, group=1, n_bins=4, reorder=True, n_shards=0):
    """Tap lowering of a pattern/connectivity-pruned conv (PatDNN/PCONV
    schemes, paper §2.1.1): per-kernel pattern masks carry NO block
    structure — every (p, q) kernel keeps its own 4-of-9 tap set — so the
    skippable unit is a single ROW of the im2col band ("tap" = input
    channel q at kernel position (i, j)), not a (bk, bn) block.

    Builds a ``core.packed.TapLayout`` for ``kernels.bsr_matmul.
    tap_gather_conv``: per group of ``group`` consecutive output filters,
    the list of band rows any filter in the group survives at, degree-
    sorted and split into ``n_bins`` bins each padded to its own max (the
    same Fig 4 load-balance move as ``pack_csc_reordered`` — connectivity-
    pruned filters carry fewer taps, so binning keeps them from paying the
    densest filter's degree).  Rows dead for EVERY group are dropped from
    the ``alive`` index entirely: whole pruned taps and whole pruned input
    channels are never even gathered into the kernel's input band.

    ``group=1`` (the default, and what ``serve.compile`` uses) stores exact
    per-filter tap lists — maximum skipping.  Larger groups widen the
    kernel's output tile but store the tap UNION of the group; since
    patterns differ per kernel, the union approaches dense quickly (for
    random 4-of-9 patterns a group of 8 keeps ~99% of taps), so wide
    groups only pay off after PatDNN-style similarity reordering.

    Works for any (P, Q, Kh, Kw) mask — 3x3 pattern masks, connectivity
    (whole-kernel) masks on arbitrary kernel sizes, or their product.

    ``n_shards > 0`` (implies ``reorder``): filter groups are distributed
    across tensor-parallel shards by the same degree-balanced
    ``shard_columns`` assignment as ``pack_csc_reordered``, then binned
    per shard with each bin padded to the cross-shard max — per-bin leaves
    gain a leading shard axis, ``perm`` becomes ``(S, G_s)`` of ORIGINAL
    group ids, ``inv_perm`` stays flat ``(G,)``.  ``alive`` remains the
    GLOBAL live-row index (replicated): every shard gathers from the same
    input band."""
    from repro.core.packed import TapLayout

    if n_shards and not reorder:
        raise ValueError("n_shards > 0 requires reorder=True (the "
                         "degree-balanced shard assignment IS a reorder)")

    w = np.asarray(w)
    mask = np.broadcast_to(np.asarray(mask), w.shape)
    assert w.ndim == 4, \
        f"pattern_lower needs a (P, Q, Kh, Kw) conv weight, got {w.shape}"
    P = w.shape[0]
    assert P % group == 0, (P, group)
    wl = conv_lower(w * mask.astype(w.dtype))          # (K, P)
    ml = conv_lower(mask) > 0
    K = wl.shape[0]
    G = P // group
    galive = ml.reshape(K, G, group).any(axis=2)       # (K, G)
    alive = np.nonzero(galive.any(axis=1))[0]          # rows live anywhere
    if len(alive) == 0:
        alive = np.zeros(1, np.int64)                  # fully-pruned layer
    ga = galive[alive]                                 # (R, G)
    cnt = ga.sum(axis=0).astype(np.int64)              # taps per group
    if n_shards:
        assign = shard_columns(cnt, n_shards)          # (S, G_s)
        S, Gs = assign.shape
        inv = np.empty(G, np.int32)
        inv[assign.reshape(-1)] = np.arange(G, dtype=np.int32)
        cnt_sh = cnt[assign]
        bin_values, bin_tidx, bin_kfull = [], [], []
        for s, e in bin_bounds(Gs, n_bins):
            Lb = max(1, int(cnt_sh[:, s:e].max()))     # cross-shard max
            vals = np.zeros((S, e - s, Lb, group), w.dtype)
            tidx = np.zeros((S, e - s, Lb), np.int32)
            for sh in range(S):
                for gi, g in enumerate(assign[sh, s:e]):
                    rows = np.nonzero(ga[:, g])[0]
                    vals[sh, gi, :len(rows)] = \
                        wl[alive[rows], g * group:(g + 1) * group]
                    tidx[sh, gi, :len(rows)] = rows
            bin_values.append(jnp.asarray(vals))
            bin_tidx.append(jnp.asarray(tidx))
            bin_kfull.append(jnp.asarray(alive[tidx], jnp.int32))
        return TapLayout(values=tuple(bin_values), t_idx=tuple(bin_tidx),
                         k_full=tuple(bin_kfull),
                         nnz=jnp.asarray(cnt_sh, jnp.int32),
                         alive=jnp.asarray(alive, jnp.int32),
                         perm=jnp.asarray(assign), inv_perm=jnp.asarray(inv),
                         group=group, shape=(K, P), n_shards=S)
    if reorder:
        order = np.argsort(-cnt, kind="stable").astype(np.int32)
        bounds = bin_bounds(G, n_bins)
    else:
        order = np.arange(G, dtype=np.int32)
        bounds = ((0, G),)
    inv = np.empty(G, np.int32)
    inv[order] = np.arange(G, dtype=np.int32)
    cnt_sorted = cnt[order]
    bin_values, bin_tidx, bin_kfull = [], [], []
    for s, e in bounds:
        Lb = max(1, int(cnt_sorted[s:e].max()) if e > s else 1)
        vals = np.zeros((e - s, Lb, group), w.dtype)
        tidx = np.zeros((e - s, Lb), np.int32)
        for gi, g in enumerate(order[s:e]):
            rows = np.nonzero(ga[:, g])[0]
            vals[gi, :len(rows)] = wl[alive[rows], g * group:(g + 1) * group]
            tidx[gi, :len(rows)] = rows
        bin_values.append(jnp.asarray(vals))
        bin_tidx.append(jnp.asarray(tidx))
        # the implicit-GEMM aux: each slot's FULL-band row alive[t_idx]
        # (tap*C + channel), from which the implicit kernel derives its
        # (dy, dx, c) input offsets — padding slots point at alive[0] with
        # zero values, so they gather a real pixel and multiply to nothing
        bin_kfull.append(jnp.asarray(alive[tidx], jnp.int32))
    return TapLayout(values=tuple(bin_values), t_idx=tuple(bin_tidx),
                     k_full=tuple(bin_kfull),
                     nnz=jnp.asarray(cnt_sorted, jnp.int32),
                     alive=jnp.asarray(alive, jnp.int32),
                     perm=jnp.asarray(order) if reorder else None,
                     inv_perm=jnp.asarray(inv) if reorder else None,
                     group=group, shape=(K, P))


def conv_tap_table(kh, kw, c, bk):
    """Static k-block -> (dy, dx, c0) offset table for implicit-GEMM conv.

    The im2col-lowered weight's row r = (dy*Kw + dx)*C + c reads input
    channel c at kernel tap (dy, dx) (``conv_lower`` row order).  Because a
    conv packing block is (bk, bn) = (bq, bp) with bq | Q (``conv_gemm_
    block``), every K-block of ``bk`` consecutive rows lies inside ONE tap:
    k-block ``kb`` covers channels [c0, c0+bk) of tap (dy, dx).  This table
    is what lets ``kernels.bsr_matmul.bsr_conv2d_implicit`` gather its x
    tile straight from the padded feature map — the patch tensor never
    exists in HBM.  Returned as a hashable tuple of (dy, dx, c0) triples so
    it can ride as static aux on ``core.packed.PackedLayout.conv_taps``.
    """
    assert c % bk == 0, (
        f"implicit conv needs the packing block bk={bk} to divide "
        f"Cin={c} so K-blocks never straddle kernel taps")
    kb_n = kh * kw * c // bk
    out = []
    for kb in range(kb_n):
        r0 = kb * bk
        t = r0 // c
        out.append((t // kw, t % kw, r0 % c))
    return tuple(out)


def conv_gemm_block(kernel_block, conv_shape):
    """Packing block for the lowered conv GEMM from the paper's kernel-block
    choice (bp over filters P, bq over channels Q): (bk, bn) = (bq, bp).
    Returns None (with a reason) when the block cannot tile the layer."""
    bp, bq = kernel_block
    P, Q, Kh, Kw = conv_shape
    if Q % bq or P % bp:
        return None, (f"kernel block {kernel_block} does not divide "
                      f"(P={P}, Q={Q})")
    return (bq, bp), None


def pad_to_uniform_csc_loop(bcs: BCS):
    """Pure-Python reference for ``pad_to_uniform_csc`` (original impl)."""
    K, N = bcs.shape
    bk, bn = bcs.block
    Kb, Nb = K // bk, N // bn
    cols = [[] for _ in range(Nb)]
    for i in range(Kb):
        for t in range(bcs.row_ptr[i], bcs.row_ptr[i + 1]):
            cols[bcs.col_idx[t]].append((i, t))
    nnz = np.asarray([len(c) for c in cols], np.int32)
    Lmax = max(1, int(nnz.max()) if len(nnz) else 1)
    vals = np.zeros((Nb, Lmax, bk, bn), bcs.values.dtype)
    kidx = np.zeros((Nb, Lmax), np.int32)
    for j in range(Nb):
        for l, (i, t) in enumerate(cols[j]):
            vals[j, l] = bcs.values[t]
            kidx[j, l] = i
    return jnp.asarray(vals), jnp.asarray(kidx), jnp.asarray(nnz)


def load_imbalance(bcs: BCS) -> float:
    """max/mean surviving blocks per row — what row-binning equalizes."""
    nnz = np.diff(bcs.row_ptr).astype(np.float64)
    if nnz.mean() == 0:
        return 1.0
    return float(nnz.max() / max(nnz.mean(), 1e-9))
