"""Offline latency model (paper §5.2.1), TPU-analytical edition.

The paper measures a lookup table of layer latencies on the target phone
(512 settings, ~30 min).  Without TPU hardware in the loop, we build the
same *interface* — latency(layer setting) -> seconds — from a three-term
roofline parameterized by the TPU v5e datasheet, with scheme/block-size
dependent efficiency factors that encode the compiler/kernel behavior:

  t = max(flops_eff / (peak * util(scheme, block)),
          bytes(scheme, block) / hbm_bw) + grid_steps * step_overhead

  * util: MXU tile utilization — blocks smaller than the 128x128 MXU tile
    waste systolic lanes (the SIMD-width analogue of the paper's mobile
    model); unstructured sparsity cannot use the MXU at all (gather bound).
  * bytes: BCS values + hierarchical index metadata + activations.
  * step_overhead: per grid-step pipeline bubble — more/smaller blocks =
    more steps (the paper's branch-overhead analogue).

`build_table` materializes the lookup-table form (the artifact the
rule-based mapper consumes); `calibrate` rescales constants against
compiled-HLO cost analysis from the dry-run."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class TPUTarget:
    name: str = "v5e"
    peak_flops: float = 197e12        # bf16
    hbm_bw: float = 819e9
    ici_bw: float = 50e9              # per link
    mxu: int = 128
    step_overhead: float = 1.5e-7     # per pallas grid step (pipeline bubble)
    gather_bw_frac: float = 0.08      # unstructured: effective HBM fraction
    vpu_frac: float = 0.02            # VPU-only compute as a peak fraction
                                      # (gather-fed paths that defeat MXU
                                      # tiling: unstructured CSR and the
                                      # pattern tap-gather kernel)


V4 = TPUTarget("v4", 275e12, 1228e9, 45e9)
V5E = TPUTarget()
V5P = TPUTarget("v5p", 459e12, 2765e9, 90e9)


def _util(scheme: str, block, mxu=128) -> float:
    if scheme in ("structured_row", "structured_col", "none"):
        return 1.0
    if scheme == "unstructured":
        return 0.0                     # handled as gather-bound
    if scheme in ("block", "block_row", "block_col", "block_punched"):
        bk, bn = block
        return min(bk, mxu) / mxu * min(bn, mxu) / mxu if bk < mxu or bn < mxu \
            else 1.0
    if scheme == "pattern":
        # 4-of-9 pattern compute maps to TPU as dense 3x3 with masked taps:
        # compute not skippable on MXU, only HBM traffic shrinks.
        return 1.0
    raise ValueError(scheme)


def pattern_executed_frac(connectivity=0.0, taps=4, positions=9) -> float:
    """Executed-tap fraction of the tap-gather kernel under a pattern
    scheme: ``taps``-of-``positions`` kernel patterns times the kernels
    that survive connectivity pruning.  This is the *executed* cost the
    mappers rank pattern picks by — when a real ``TapLayout`` exists, pass
    its measured ``1 - flops_saved`` (which also counts bin padding) as
    ``executed_frac`` instead."""
    return taps / positions * (1.0 - connectivity)


def im2col_x_frac(taps, implicit=True) -> float:
    """Activation-traffic multiplier on a conv-as-GEMM's x bytes (M*K).

    The memory-traffic term the mappers price the implicit path with: a
    conv lowered to an im2col GEMM nominally reads M*K activation bytes —
    a ``taps`` = Kh*Kw blow-up of the feature map.  The implicit-GEMM
    kernels (``kernels.bsr_matmul.bsr_conv2d_implicit`` /
    ``tap_gather_conv_implicit``) read the padded feature map once instead
    (frac 1/taps, the halo ignored as second-order); the MATERIALIZED path
    additionally writes the patch tensor to HBM and reads it back on top
    of the original feature-map read (2 + 1/taps).  FLOPs are identical —
    only DRAM bytes move, which is exactly what decides the conv layers
    of a memory-bound mobile/real-time deployment."""
    taps = max(1, int(taps))
    return 1.0 / taps if implicit else 2.0 + 1.0 / taps


def matmul_latency(M, K, N, *, scheme="none", block=(128, 128),
                   compression=1.0, target: TPUTarget = V5E,
                   dtype_bytes=2, value_bytes=None, executed_frac=None,
                   x_frac=None) -> float:
    """One FC/CONV-as-GEMM layer: y(M,N) = x(M,K) @ w(K,N) with the given
    pruning scheme at `compression` (param reduction factor).

    ``executed_frac`` overrides the raw density with the fraction of dense
    MACs the kernel actually executes under its padded layout (pattern
    scheme: measured tap savings from a ``core.packed.TapLayout``) — the
    executed-cost hook the mappers use so a pattern pick is ranked by what
    the tap-gather kernel runs, not by raw mask density.

    ``value_bytes`` is the stored bytes per surviving WEIGHT value (the
    quantized serving path of ``core.quant``: 1 for int8 values, while
    activations stay at ``dtype_bytes``).  None keeps ``dtype_bytes``.
    When it differs, the sparse branches add the fp32 scale traffic the
    dequantizing kernels actually read: one scale per surviving block
    ("block" granularity) for the block schemes, one per output filter
    for the pattern scheme (tap layouts quantize per-filter).  Compute
    terms are unchanged — the kernels dequantize into the same fp32
    accumulation, so quantization only moves the HBM term, which is
    exactly the post-implicit-GEMM bottleneck it attacks.

    ``x_frac`` scales the activation DRAM bytes (memory-traffic term) for
    conv-as-GEMM layers: pass ``im2col_x_frac(kh*kw)`` to price the
    implicit-GEMM path (feature map read once, no patch tensor) or
    ``im2col_x_frac(kh*kw, implicit=False)`` for the materialized patch
    write+read.  None (the default) keeps the plain GEMM accounting (and,
    on the pattern branch, the legacy alive-band estimate)."""
    density = 1.0 / max(compression, 1.0)
    dense_flops = 2.0 * M * K * N
    x_b = M * K * dtype_bytes
    y_b = M * N * dtype_bytes
    w_dense_b = K * N * dtype_bytes
    v_b = dtype_bytes if value_bytes is None else value_bytes

    if scheme == "none":
        t_c = dense_flops / target.peak_flops
        t_m = (x_b * (1.0 if x_frac is None else x_frac)
               + y_b + w_dense_b) / target.hbm_bw
        steps = max(1, (M // target.mxu) * (N // target.mxu))
        return max(t_c, t_m) + steps * target.step_overhead

    if scheme == "unstructured":
        # CSR gather: no MXU, index+value traffic at degraded bandwidth
        w_b = density * K * N * (v_b + 4)
        t_m = (x_b + y_b + w_b) / (target.hbm_bw * target.gather_bw_frac)
        t_c = density * dense_flops / (target.peak_flops * target.vpu_frac)
        return max(t_c, t_m)

    if scheme in ("structured_row", "structured_col"):
        # dense GEMM with a shrunk dimension
        if scheme == "structured_row":
            N2, K2 = N * density, K
        else:
            N2, K2 = N, K * density
        return matmul_latency(M, int(max(K2, 1)), int(max(N2, 1)),
                              scheme="none", target=target,
                              dtype_bytes=dtype_bytes)

    if scheme == "pattern":
        # tap-gather kernel (kernels.bsr_matmul.tap_gather_conv): only the
        # executed taps are gathered and multiplied — compute scales with
        # the executed-tap fraction at VPU efficiency (per-filter tap sets
        # defeat MXU tiling), HBM shrinks to surviving values + 4-byte tap
        # ids + the alive activation band.  One grid step per (M tile,
        # filter group) at group=1 — the serve-path layout.
        frac = executed_frac if executed_frac is not None else density
        t_c = frac * dense_flops / (target.peak_flops * target.vpu_frac)
        w_b = frac * K * N * (v_b + 4)
        if v_b != dtype_bytes:
            w_b += 4 * N               # per-filter fp32 scales ("out")
        # activation traffic: explicit x_frac (implicit kernel reads the
        # feature map, materialized pays the patch round-trip); the legacy
        # default approximates the alive-band read of the gathered path
        x_eff = x_frac if x_frac is not None else min(1.0, 9 * frac)
        t_m = (x_b * x_eff + y_b + w_b) / target.hbm_bw
        steps = max(1.0, max(1, M // 512) * N)
        return max(t_c, t_m) + steps * target.step_overhead

    # block / block_punched: skip zero blocks, pay utilization + per-step
    # overhead for sub-MXU tiles
    bk, bn = block
    util = _util(scheme, block, target.mxu)
    n_blocks_alive = density * (K // bk) * (N // bn)
    eff_flops = density * dense_flops
    t_c = eff_flops / (target.peak_flops * util)
    idx_b = 4 * n_blocks_alive + 4 * (K // bk)
    w_b = density * K * N * v_b + idx_b
    if v_b != dtype_bytes:
        w_b += 4 * n_blocks_alive      # per-block fp32 scales
    t_m = (x_b * (1.0 if x_frac is None else x_frac)
           + y_b + w_b) / target.hbm_bw
    # grid steps at the autotuned M-tile (512): each M-tile revisits every
    # surviving weight block (kernels/bsr_matmul.py grid structure)
    steps = max(1.0, n_blocks_alive * max(1, M // 512))
    return max(t_c, t_m) + steps * target.step_overhead


def structured_baseline(M, K, N, compression, target=V5E) -> float:
    return matmul_latency(M, K, N, scheme="structured_row",
                          compression=compression, target=target)


def conv_as_gemm(feat, in_ch, out_ch, kh, kw, batch=1):
    """im2col GEMM dims for a conv layer: M=B*H*W, K=Cin*kh*kw, N=Cout."""
    return batch * feat * feat, in_ch * kh * kw, out_ch


# ---------------------------------------------------------------------------
# The offline table (paper: 512 settings measured in ~30 min on-device)
# ---------------------------------------------------------------------------

def build_table(target: TPUTarget = V5E,
                feats=(7, 14, 28, 56), chans=(64, 128, 256, 512),
                schemes=("none", "unstructured", "structured_row", "pattern",
                         "block"),
                blocks=((4, 4), (8, 16), (16, 32), (32, 64), (64, 128),
                        (128, 128), (128, 256)),
                compressions=(1, 2, 4, 8, 12, 16)) -> dict:
    table = {}
    for f, c, s, comp in itertools.product(feats, chans, schemes,
                                           compressions):
        M, K, N = conv_as_gemm(f, c, c, 3, 3)
        blist = blocks if s.startswith("block") else ((0, 0),)
        for b in blist:
            if s.startswith("block") and (K % b[0] or N % b[1]):
                continue
            key = (f, c, s, b, comp)
            table[key] = matmul_latency(M, K, N, scheme=s, block=b,
                                        compression=comp, target=target)
    return table


def calibrate(target: TPUTarget, measured_flops_per_s=None,
              measured_bytes_per_s=None) -> TPUTarget:
    """Rescale datasheet constants to dry-run-derived effective rates."""
    kw = {}
    if measured_flops_per_s:
        kw["peak_flops"] = measured_flops_per_s
    if measured_bytes_per_s:
        kw["hbm_bw"] = measured_bytes_per_s
    return replace(target, **kw)
