"""Mixtral-8x7B — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].  SWA(4096) bounds the KV cache, so long_500k decode
is legal (window cache, O(window) memory — DESIGN.md §Arch-applicability)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    n_experts=8, top_k=2, sliding_window=4096,
    rope_theta=1e6, supports_long=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, head_dim=16, n_experts=4,
                       top_k=2, sliding_window=32, remat="none")
