"""Minitron-8B — pruned Nemotron dense GQA [arXiv:2407.14679; hf].

(Itself a *pruned* model — the paper's structured-pruning lineage.)"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, head_dim=128,
    rope_theta=10000.0, attn_shard="heads",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, head_dim=16, remat="none")
