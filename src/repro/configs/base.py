"""Architecture config schema + registry.

One module per assigned architecture lives next to this file; each exposes
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced same-family
config for CPU tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    # structure
    sliding_window: int = 0
    rope_theta: float = 10000.0
    cross_attn_interval: int = 0   # vlm: one cross-attn layer per this many
    n_enc_layers: int = 0          # encdec encoder depth
    n_frontend_tokens: int = 1024  # audio/vlm stub embedding count
    # distribution / runtime
    attn_shard: str = "heads"      # "heads" | "seq" (when n_heads % tp != 0)
    train_shard_mode: str = "fsdp"  # "fsdp" (ZeRO-3: weights gathered
    #   per layer, tokens sharded over ALL axes) | "tp" (Megatron).  At
    #   train_4k token counts, activations >> weights, so FSDP's weight
    #   all-gathers beat TP's activation collectives ~10x (EXPERIMENTS.md
    #   §Perf iter 2).  Inference (prefill/decode) always lowers with TP.
    optimizer: str = "adamw"       # "adamw" | "adafactor" (>=70B)
    remat: str = "full"            # "none" | "full"
    supports_long: bool = False    # sub-quadratic 500k decode legal
    kv_chunk: int = 1024   # flash-chunk size.  §Perf iter 3 measured
    #   single-chunk (4096) at 1.4x MORE collective traffic than chunked —
    #   the full (B,H,Sq,Sk) score tensor gets resharded in CP mode —
    #   so chunked stays the default (refuted hypothesis, kept on record)
    moe_group: int = 1024
    unroll_layers: bool = False    # python-loop layer stacks (cost probes:
    #   lax.scan bodies are counted ONCE by XLA cost analysis, so the
    #   dry-run extrapolates true per-layer cost from unrolled L=1/L=2)

    @property
    def hd(self):
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


ARCH_IDS = [
    "seamless_m4t_large_v2", "yi_9b", "granite_8b", "minitron_8b",
    "phi3_medium_14b", "mamba2_1p3b", "mixtral_8x7b", "kimi_k2_1t_a32b",
    "hymba_1p5b", "llama_3p2_vision_90b",
]

# canonical external ids (as given in the assignment) -> module names
ALIASES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "yi-9b": "yi_9b",
    "granite-8b": "granite_8b",
    "minitron-8b": "minitron_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "mamba2-1.3b": "mamba2_1p3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "hymba-1.5b": "hymba_1p5b",
    "llama-3.2-vision-90b": "llama_3p2_vision_90b",
}


def get(name: str, smoke: bool = False) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


SHAPES = {
    # shape id: (seq_len, global_batch, step kind)
    "train_4k":    dict(seq=4096,   batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,  batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,  batch=128, kind="decode"),
    "long_500k":   dict(seq=524288, batch=1,   kind="decode"),
}
