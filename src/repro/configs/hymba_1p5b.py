"""Hymba-1.5B — hybrid parallel attention + mamba heads [arXiv:2411.13676; hf].

25 heads don't divide tp=16 -> "seq" attention sharding.  SSM branch:
d_inner=3200, headdim=100 -> 32 SSD heads (divisible), state=16.  Sliding-
window attention (1024) + SSM state => sub-quadratic, runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    ssm_state=16, ssm_headdim=100, ssm_expand=2,
    sliding_window=1024, attn_shard="seq", supports_long=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, head_dim=16, ssm_state=8,
                       ssm_headdim=16, sliding_window=32, remat="none",
                       attn_shard="heads")
