"""Config registry + ``input_specs``: ShapeDtypeStruct stand-ins for every
model input of every (arch × shape) cell — weak-type-correct, shardable, no
device allocation (the dry-run pattern)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SHAPES, ARCH_IDS, ALIASES, get

__all__ = ["ArchConfig", "SHAPES", "ARCH_IDS", "ALIASES", "get",
           "input_specs", "cell_is_supported"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cell_is_supported(cfg: ArchConfig, shape_id: str) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §3)."""
    if shape_id == "long_500k" and not cfg.supports_long:
        return False, ("SKIP: pure full-attention arch — 500k dense-KV decode "
                       "is quadratic with no SWA/SSM escape (DESIGN.md)")
    return True, ""


@functools.lru_cache(maxsize=None)
def _abstract_cache_spec(cfg: ArchConfig, batch: int, seq: int):
    from repro.models import transformer as T

    def build():
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        return T.init_cache(params, cfg, batch, seq)
    return jax.eval_shape(build)


def input_specs(cfg: ArchConfig, shape_id: str, smoke: bool = False) -> dict:
    """Returns {name: ShapeDtypeStruct} for the given step kind.

    train:   tokens/labels (B, S) int32 (+ frontend embeds for encdec/vlm)
    prefill: tokens (B, S) int32 (+ frontend)
    decode:  token/pos (B, 1) int32 + the full KV/SSM cache pytree
    """
    sh = SHAPES[shape_id]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    specs = {}
    if kind in ("train", "prefill"):
        specs["tokens"] = _sds((B, S), jnp.int32)
        if kind == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token over a seq_len cache
        specs["token"] = _sds((B, 1), jnp.int32)
        specs["pos"] = _sds((B, 1), jnp.int32)
        specs["cache"] = _abstract_cache_spec(cfg, B, S)
    if cfg.family in ("encdec", "vlm") and kind != "decode":
        specs["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                 jnp.bfloat16)
    return specs
