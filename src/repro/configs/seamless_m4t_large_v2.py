"""SeamlessM4T-large-v2 — encoder-decoder, multimodal (audio)
[arXiv:2308.11596; hf].  24 encoder + 24 decoder layers, d=1024, 16H MHA
(GQA kv=16), d_ff=8192, vocab=256206.  The speech frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, n_frames, d_model).
Decode shapes lower the decoder serve_step with cached encoder memory."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    n_frontend_tokens=1024,
)

SMOKE = CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
                       n_frontend_tokens=32, remat="none")
