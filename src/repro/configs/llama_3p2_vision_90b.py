"""Llama-3.2-Vision-90B — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision scaled; unverified].

100 layers = 20 groups of (4 self-attn + 1 gated cross-attn); vision tower
is a STUB (input_specs provides patch embeddings (B, n_patches, d_model)).
FSDP for Adam state.  long_500k skipped: pure full attention (DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    cross_attn_interval=5, n_frontend_tokens=1024,
    rope_theta=5e5, optimizer="adafactor",
)

SMOKE = CONFIG.replace(n_layers=10, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, head_dim=16,
                       cross_attn_interval=5, n_frontend_tokens=16,
                       remat="none")
