"""Phi-3-medium-14B — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40 heads / kv=10 do not divide the tp=16 model axis -> attention uses the
"seq" (context-parallel) sharding mode (DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352, head_dim=128,
    rope_theta=10000.0, attn_shard="seq",
    # measured: seq-CP attention + Megatron-TP beats FSDP here
    # (4.5s vs 7.5s collective term, EXPERIMENTS.md §Perf notes)
    train_shard_mode="tp",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=60, n_heads=5, n_kv_heads=5,
                       d_ff=128, vocab=256, head_dim=12, remat="none")
