"""Mamba2-1.3B — SSD state-space duality, attention-free [arXiv:2405.21060].

48 blocks of pure mamba2 mixers (d_ff=0, no attention).  d_inner=4096,
headdim=64 -> 64 SSD heads, state=128.  Sub-quadratic: runs long_500k.
Paper-technique note (DESIGN.md §Arch-applicability): block-based pruning on
in/out projections; conv1d + SSD params never pruned (depthwise rule §5.2.4);
pattern-based pruning inapplicable (no 3x3 convs)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
    supports_long=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, vocab=256, ssm_state=16,
                       ssm_headdim=16, remat="none")
