"""Kimi K2 1T-A32B — trillion-param MoE, 384 experts top-8 (paper-table)
[arXiv:2501.kimi2; unverified].  d_ff=2048 per expert (fine-grained MoE).
FSDP + Adafactor (Adam fp32 state for 1T params cannot fit 512 v5e chips).
Experts shard 384/16 = 24 per model shard (EP)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    n_experts=384, top_k=8,
    rope_theta=5e6, optimizer="adafactor", moe_group=256,  # == per-shard seq slice (4096/16):
    # groups never span model shards, so the (B,S)->(G,Sg) reshape is
    # collective-free (EXPERIMENTS.md §Perf kimi iter 3)
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=64, vocab=256, head_dim=16, n_experts=8,
                       top_k=2, remat="none", moe_group=64)
