"""Deterministic chaos harness: seeded fault injectors for the serving
stack.

Every injector is a pure function of its arguments plus an explicit
integer ``seed`` (``numpy.random.RandomState`` — never wall clock, never
global RNG state), so a fault scenario REPLAYS exactly: the fault-matrix
suite (tests/test_faults.py) and the gated ``benchmarks/bench_faults.py``
run the same injections and must see the same recoveries, token streams,
and audit events every time.

The four injectors cover the fault taxonomy of docs/architecture.md
("Fault tolerance"):

  ``bitflip_packed_leaf``  corrupt a packed layout in process memory
                           (saturate a float value's exponent bits to
                           non-finite, or knock an index leaf out of
                           range) -> caught by ``core.validate``, layer
                           degrades to masked-dense
  ``nan_slot``             poison one engine slot's cache row with NaN ->
                           caught by the fused finite probe, slot
                           quarantined, neighbors bit-identical
  ``expire_deadline``      zero a request's deadline/TTL budget -> evicted
                           by the scheduler sweep with a typed event
  ``crash_publish``        simulate an artifact writer dying mid-publish
                           (stale staging husk, or a torn final dir with
                           no manifest) -> store ignores/falls back to a
                           fresh pack

Each returns a ``FaultRecord`` describing exactly what was injected, so
assertions can name the fault they recovered from.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

from repro.core.packed import PackedLayout, TapLayout
from repro.serve import kvcache as KV

# the fault-matrix axis: one name per injector, shared by the suite and
# the chaos bench so "matrix green" means the same thing in both
FAULT_KINDS = ("corrupt_leaf", "nan_slot", "expired_deadline",
               "crashed_publish")

# exponent-saturation masks per float itemsize: OR-ing one in turns any
# float into Inf/NaN — a genuine bit-level corruption that the finite
# checks are guaranteed to see (a mid-mantissa flip could stay finite and
# no validator can know the value is wrong)
_EXP_MASK = {8: (np.uint64, np.uint64(0x7FF0000000000000)),
             4: (np.uint32, np.uint32(0x7F800000)),
             2: (np.uint16, np.uint16(0x7F80))}


@dataclasses.dataclass(frozen=True)
class FaultRecord:
    """What a chaos injector actually did: the fault ``kind`` (one of
    ``FAULT_KINDS``), the ``target`` it hit (layer path, slot, request id,
    or artifact key), and a human-readable ``detail``."""

    kind: str
    target: str
    detail: str


def _packed_layers(tree):
    """Walk an exec-param tree and list ``(path, node)`` for every node
    carrying a real packed layout, in deterministic traversal order."""
    found = []

    def walk(node, path):
        if not isinstance(node, dict):
            return
        packed = node.get("packed")
        if isinstance(packed, (PackedLayout, TapLayout)):
            found.append((path, node))
        for k, v in node.items():
            if k != "packed":
                walk(v, f"{path}/{k}" if path else k)

    walk(tree, "")
    return found


def _skeleton_swap(tree, target_node, new_node):
    """Copy the dict skeleton of ``tree`` (array leaves shared) with ONE
    node object replaced — the injected tree never aliases the input's
    dicts, so the healthy tree stays healthy for oracle runs."""
    def walk(node):
        if node is target_node:
            return new_node
        if not isinstance(node, dict):
            return node
        return {k: walk(v) for k, v in node.items()}

    return walk(tree)


def bitflip_packed_leaf(exec_params, *, seed=0):
    """Corrupt one packed layout of ``exec_params`` in memory, seeded.

    Float ``values``: saturate the exponent bits of one (seeded) element
    to non-finite — detected by ``core.validate``'s ``non_finite`` check.
    Quantized (int) ``values``: knock one index-leaf entry out of range
    instead — detected by the ``index_range`` check.  Either way the
    corrupt layout CANNOT reach a kernel: ``degrade_invalid_layers``
    retires it to masked-dense.

    Returns ``(injected_tree, FaultRecord)``; the input tree is untouched
    (skeleton-copied) so it remains the healthy oracle.
    """
    layers = _packed_layers(exec_params)
    if not layers:
        raise ValueError("no packed layouts to corrupt")
    rng = np.random.RandomState(seed)
    path, node = layers[int(rng.randint(len(layers)))]
    layout = node["packed"]
    bins = [b for b, v in enumerate(layout.values) if np.asarray(v).size]
    b = bins[int(rng.randint(len(bins)))]
    v = np.asarray(layout.values[b])
    if np.issubdtype(v.dtype, np.integer):
        # int8 values: corrupt the index leaf instead (index_range)
        idx_name = "k_idx" if isinstance(layout, PackedLayout) else "t_idx"
        idx = np.array(getattr(layout, idx_name)[b])
        i = int(rng.randint(idx.size))
        idx.reshape(-1)[i] = np.iinfo(np.int32).max // 2
        leaves = list(getattr(layout, idx_name))
        leaves[b] = idx
        new_layout = dataclasses.replace(layout, **{idx_name: tuple(leaves)})
        detail = f"{idx_name}[bin {b}] flat[{i}] -> out of range"
    else:
        v = v.copy()
        flat = v.reshape(-1)
        i = int(rng.randint(flat.size))
        utype, mask = _EXP_MASK[v.dtype.itemsize]
        view = flat.view(utype)
        view[i] |= mask
        leaves = list(layout.values)
        leaves[b] = v
        new_layout = dataclasses.replace(layout, values=tuple(leaves))
        detail = f"values[bin {b}] flat[{i}] -> exponent saturated"
    new_node = dict(node, packed=new_layout)
    return (_skeleton_swap(exec_params, node, new_node),
            FaultRecord("corrupt_leaf", path, detail))


def nan_slot(engine, slot, *, value=float("nan")):
    """Poison slot ``slot`` of a running ``ServingEngine``'s cache with
    ``value`` (NaN) — the next batched decode yields non-finite logits for
    that slot only, the fused finite probe quarantines it, and every other
    slot's tokens stay bit-identical (slots share weights, never
    activations).  Returns a ``FaultRecord``."""
    engine.cache = KV.poison_slot(engine.cache, slot, value=value)
    return FaultRecord("nan_slot", f"slot {slot}",
                       f"cache row overwritten with {value}")


def expire_deadline(engine, rid):
    """Zero request ``rid``'s deadline budgets: a running request is
    evicted (reason ``deadline_expired``) at the next sweep, a queued one
    expires from the queue — either way with a typed audit event, never a
    hang.  Returns a ``FaultRecord``."""
    req = engine.requests[rid]
    req.deadline_steps = 0
    req.queue_ttl = -1
    return FaultRecord("expired_deadline", f"rid {rid}",
                       f"deadline budgets zeroed while {req.status}")


def crash_publish(artifact_dir, key, *, stage="staging", seed=0):
    """Simulate an artifact writer crashing mid-publish under ``key``.

    ``stage="staging"``: leave a stale ``.tmp_*`` staging husk with a
    half-written array file — exactly what a killed writer leaves behind;
    the store must ignore it (publishes are tmp + atomic rename).
    ``stage="torn"``: a final directory WITHOUT its manifest (external
    corruption after publish) — ``load_grafted`` must return ``None`` so
    the caller repacks.  Seeded garbage bytes; returns a ``FaultRecord``.
    """
    d = pathlib.Path(artifact_dir)
    rng = np.random.RandomState(seed)
    junk = rng.bytes(64)
    if stage == "staging":
        husk = d / f".tmp_{key}_31337"
        husk.mkdir(parents=True, exist_ok=True)
        (husk / "arrays.npz").write_bytes(junk)
        detail = f"stale staging husk {husk.name}"
    elif stage == "torn":
        torn = d / key
        torn.mkdir(parents=True, exist_ok=True)
        (torn / "arrays.npz").write_bytes(junk)
        manifest = torn / "MANIFEST.json"
        if manifest.exists():
            manifest.unlink()
        detail = "final dir without MANIFEST.json"
    else:
        raise ValueError(f"unknown stage {stage!r}")
    return FaultRecord("crashed_publish", str(key), detail)
