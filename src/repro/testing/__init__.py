"""Deterministic test infrastructure: the seeded chaos harness
(``repro.testing.faults``) that drives the fault-matrix suite and
``benchmarks.bench_faults``."""
