"""Training step factory: loss (+MoE aux, + the paper's reweighted
group-lasso penalty when pruning is active), grad clip, optimizer update.

Masked-dense semantics: pruning masks are applied to the params *before* the
forward pass, so gradients are automatically masked and XLA fuses the mask
multiply into matmul operands (the training-time path; the BCS Pallas kernel
is the serving-time path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim.adamw import (make_optimizer, cosine_schedule,
                               clip_by_global_norm)

tmap = jax.tree_util.tree_map


def apply_masks(params, masks):
    """masks is a full-structure tree: {0,1} arrays for prunable leaves,
    scalar-1.0 sentinels elsewhere (see reweighted.masks_for_spec)."""
    if masks is None:
        return params
    return tmap(lambda p, m: p if m.ndim == 0 else p * m.astype(p.dtype),
                params, masks)


def make_loss_fn(cfg: ArchConfig, dist=None, aux_weight=0.01,
                 reweighted=None):
    """reweighted: optional repro.core.reweighted.ReweightedConfig — adds the
    paper's Eq.(1) penalty sum_i R(alpha_i, W_i)."""

    def loss_fn(params, batch, masks=None, alphas=None):
        p = apply_masks(params, masks)
        logits, aux = T.forward(p, cfg, batch["tokens"],
                                frontend=batch.get("frontend"), dist=dist)
        ce = L.cross_entropy(logits, batch["labels"])
        total = ce + aux_weight * aux
        if reweighted is not None and alphas is not None:
            from repro.core.reweighted import penalty
            total = total + reweighted.lam * penalty(params, alphas,
                                                     reweighted)
        return total, ce

    return loss_fn


def make_train_step(cfg: ArchConfig, dist=None, lr=3e-4, reweighted=None,
                    grad_accum=1, compress_cross_pod=False):
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    loss_fn = make_loss_fn(cfg, dist=dist, reweighted=reweighted)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, masks=None, alphas=None):
        if grad_accum > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (tot, ce), g = grad_fn(params, mb, masks, alphas)
                return (tmap(jnp.add, gacc, g), lacc + ce), None
            mbs = tmap(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zeros = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, ce_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = tmap(lambda g: g / grad_accum, grads)
            ce = ce_sum / grad_accum
        else:
            (tot, ce), grads = grad_fn(params, batch, masks, alphas)
        grads, gnorm = clip_by_global_norm(grads)
        lr_t = cosine_schedule(opt_state["step"], lr)
        params, opt_state = opt_update(grads, opt_state, params, lr_t)
        return params, opt_state, {"loss": ce, "grad_norm": gnorm}

    return opt_init, train_step
