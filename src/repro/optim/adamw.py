"""Optimizers (pure JAX): AdamW with fp32 state, and Adafactor (factored
second moment, no first moment) for the >=70B archs where Adam state cannot
fit HBM.  Plus cosine LR schedule and global-norm clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def cosine_schedule(step, base_lr, warmup=100, total=10000, min_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * (step + 1) / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm=1.0):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads), gn


# -- AdamW -------------------------------------------------------------------

def adamw_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": tmap(z, params), "v": tmap(z, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    new_m = tmap(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
                 grads, state["m"])
    new_v = tmap(lambda g, v: b2 * v + (1 - b2) *
                 jnp.square(g.astype(jnp.float32)), grads, state["v"])

    def upd(p, m, v):
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_p = tmap(upd, params, new_m, new_v)
    return new_p, {"m": new_m, "v": new_v, "step": step}


# -- Adafactor ----------------------------------------------------------------

def adafactor_init(params):
    def st(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": tmap(st, params), "step": jnp.zeros((), jnp.int32)}


def _map3(fn, grads, fstate, params):
    """tree_map over params-structure with fstate's per-param dicts as leaves."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_f = treedef.flatten_up_to(fstate)
    outs = [fn(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_f = treedef.unflatten([o[1] for o in outs])
    return new_p, new_f


def adafactor_apply(grads, state, params, lr, decay=0.99, eps=1e-30,
                    clip_thresh=1.0):
    step = state["step"] + 1

    def upd(g, f, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * f["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + 1e-12)
            nf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            u = g / (jnp.sqrt(v) + 1e-12)
            nf = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_thresh)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nf

    new_p, new_f = _map3(upd, grads, state["f"], params)
    return new_p, {"f": new_f, "step": step}


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "adafactor":
        return adafactor_init, adafactor_apply
    raise ValueError(kind)
