"""Paper Fig 7 / Remark 1: pattern vs block-punched accuracy on EASY vs
HARD tasks (same compression on 3x3 layers only).

Each pruned row now also reports what its scheme EXECUTES, not just raw
mask density: pattern masks are tap-lowered (``core.bcs.pattern_lower``)
and report the mean executed-tap savings of the padded ``TapLayout``
(what ``kernels.bsr_matmul.tap_gather_conv`` actually multiplies), block
masks are im2col-packed and report the executed-L savings of the
``PackedLayout`` — so the accuracy trade-off of Remark 1 sits next to the
executed cost each pick compiles to."""

from benchmarks.common import train_convnet, eval_convnet
from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.kernels import ops
from repro.models import convnet as C


def _masks(params, scheme):
    masks = {}
    for (name, out, kh, kw, stride, dw) in C.VGG_TINY:
        if dw or kh != 3:
            continue
        w = params[name]["w"]
        if scheme == "pattern":
            masks[name] = R.pattern_mask(w, connectivity_rate=0.5)
        else:
            if w.shape[0] % 4 or w.shape[1] % 4:
                continue
            masks[name] = R.block_punched_mask(w, (4, 4), rate=0.78)
    return masks


def _executed_saving(params, masks, scheme):
    """Mean executed-FLOP savings across the pruned layers, through the
    layout each scheme compiles to (tap lists vs BCS blocks)."""
    saved = []
    for name, mask in masks.items():
        w = params[name]["w"] * mask
        if scheme == "pattern":
            saved.append(ops.pack_taps(w, mask, n_bins=4).flops_saved)
        else:
            gemm_block, _ = BCS.conv_gemm_block((4, 4), w.shape)
            saved.append(ops.pack(BCS.conv_lower(w), BCS.conv_lower(mask),
                                  gemm_block, reorder=True,
                                  n_bins=4).flops_saved)
    return sum(saved) / max(len(saved), 1)


def bench(fast=True):
    steps = 150 if fast else 400
    rows = []
    for hard in (False, True):
        dense = train_convnet(steps=steps, hard=hard, seed=1)
        acc_d = eval_convnet(dense, hard=hard)
        rows.append((f"fig7,dense,{'hard' if hard else 'easy'}", 0.0,
                     f"acc={acc_d:.3f}"))
        for scheme in ("pattern", "block"):
            masks = _masks(dense, scheme)
            p = train_convnet(steps=steps // 2, params=dense, masks=masks,
                              hard=hard)
            acc = eval_convnet(p, masks=masks, hard=hard)
            saving = _executed_saving(p, masks, scheme)
            rows.append((f"fig7,{scheme},{'hard' if hard else 'easy'}",
                         0.0, f"acc={acc:.3f};drop={acc_d - acc:.3f};"
                         f"mean_flops_saved_exec={saving:.2f}"))
    return rows
