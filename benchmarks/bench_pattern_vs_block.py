"""Paper Fig 7 / Remark 1: pattern vs block-punched accuracy on EASY vs
HARD tasks (same compression on 3x3 layers only)."""

from benchmarks.common import train_convnet, eval_convnet
from repro.core import regularity as R
from repro.models import convnet as C


def _masks(params, scheme):
    masks = {}
    for (name, out, kh, kw, stride, dw) in C.VGG_TINY:
        if dw or kh != 3:
            continue
        w = params[name]["w"]
        if scheme == "pattern":
            masks[name] = R.pattern_mask(w, connectivity_rate=0.5)
        else:
            if w.shape[0] % 4 or w.shape[1] % 4:
                continue
            masks[name] = R.block_punched_mask(w, (4, 4), rate=0.78)
    return masks


def bench(fast=True):
    steps = 150 if fast else 400
    rows = []
    for hard in (False, True):
        dense = train_convnet(steps=steps, hard=hard, seed=1)
        acc_d = eval_convnet(dense, hard=hard)
        rows.append((f"fig7,dense,{'hard' if hard else 'easy'}", 0.0,
                     f"acc={acc_d:.3f}"))
        for scheme in ("pattern", "block"):
            masks = _masks(dense, scheme)
            p = train_convnet(steps=steps // 2, params=dense, masks=masks,
                              hard=hard)
            acc = eval_convnet(p, masks=masks, hard=hard)
            rows.append((f"fig7,{scheme},{'hard' if hard else 'easy'}",
                         0.0, f"acc={acc:.3f};drop={acc_d - acc:.3f}"))
    return rows
