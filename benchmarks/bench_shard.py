"""Tensor-parallel PackedLayout sharding — degree balance + modeled scaling.

Two fixture classes:

  * ``shard_balance`` rows — the skewed-degree fixture (every 8th block
    column dense, a long degree-1..3 tail: the worst case for contiguous
    shard assignment).  ``bcs.shard_columns`` greedy-LPT assignment must
    keep the straggler factor — max/mean executed blocks were each shard
    padded to its OWN bin maxima — at or below 1.15 (asserted here AND
    regression-gated lower-is-better via the baseline), where contiguous
    assignment (``naive_balance``, reported ungated) lands far higher.
    The us column is the REAL wall time of the vmapped per-shard kernel
    (``ops.sparse_linear`` on the sharded layout), and parity against the
    unsharded oracle is asserted bit-identical (per-column accumulation
    order is preserved by construction).
  * ``tp_model`` row — a decode-shaped 4k x 4k FC under whole-block
    pruning.  ``tp_speedup`` is the MODELED parallel speedup: unsharded
    executed blocks over the per-device executed blocks of the tp=4
    layout (each shard pads its bins to the cross-shard max, so the
    straggler IS the per-device cost).  Deterministic layout accounting —
    no wall noise — gated loose at the wall threshold because cross-shard
    padding moves with the degree draw.

Emitted rows land in BENCH_shard.json under ``run.py --json``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer_us
from repro.kernels import ops

MAX_BALANCE = 1.15


def _skewed_fixture(seed=0, K=128, N=256, bk=8, bn=8):
    """A few full-degree block columns plus a sparse tail (test_sharding's
    skewed fixture, re-derived here so the bench stays self-contained)."""
    rng = np.random.default_rng(seed)
    Kb, Nb = K // bk, N // bn
    mb = np.zeros((Kb, Nb), bool)
    for j in range(Nb):
        deg = Kb if j % 8 == 0 else 1 + int(rng.integers(0, 3))
        mb[rng.permutation(Kb)[:deg], j] = True
    w = rng.standard_normal((K, N)).astype(np.float32)
    return w, np.kron(mb, np.ones((bk, bn), bool)), (bk, bn)


def _contiguous_balance(cnt, bin_sizes_fn, S):
    """Straggler factor of the NAIVE contiguous column assignment — what
    sharding without ``shard_columns`` would cost."""
    cnt = np.asarray(cnt)
    per = cnt.shape[0] // S
    loads = []
    for s in range(S):
        seg = np.sort(cnt[s * per:(s + 1) * per])[::-1]
        loads.append(bin_sizes_fn(seg))
    loads = np.asarray(loads, np.float64)
    return float(loads.max() / loads.mean())


def _balance_row(S):
    w, mask, block = _skewed_fixture()
    bk, bn = block
    pk = ops.pack(w, mask, block, n_shards=S, use_cache=False)
    bal = pk.shard_balance
    assert bal <= MAX_BALANCE, (
        f"tp={S} shard balance {bal:.3f} > {MAX_BALANCE}: shard_columns "
        "is no longer equalizing per-shard executed blocks")
    cnt = mask[::bk, ::bn].sum(axis=0).astype(np.int64)

    def executed(seg):     # same binning geometry as the packed layout
        sizes = pk.bin_sizes
        out, start = 0.0, 0
        for sz in sizes:
            out += sz * max(seg[start:start + sz].max(initial=0), 1)
            start += sz
        return out

    naive = _contiguous_balance(cnt, executed, S)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (8, w.shape[0])).astype(np.float32))
    ref = ops.sparse_linear(x, packed=ops.pack(w, mask, block, reorder=True,
                                               use_cache=False))
    fn = jax.jit(lambda xx: ops.sparse_linear(xx, packed=pk))
    np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(ref))
    us = timer_us(fn, x)
    return (f"shard_balance,skewed{w.shape[0]}x{w.shape[1]},tp={S}", us,
            f"shard_balance={bal:.3f};naive_balance={naive:.3f};"
            f"executed_blocks={pk.executed_blocks}")


def _tp_model_row(S=4, K=4096, N=4096, block=(128, 128), keep=0.125):
    rng = np.random.default_rng(3)
    kb = rng.random((K // block[0], N // block[1])) < keep
    mask = np.kron(kb, np.ones(block, bool))
    w = (rng.standard_normal((K, N)) * 0.1).astype(np.float32)
    base = ops.pack(w, mask, block, reorder=True, use_cache=False)
    pk = ops.pack(w, mask, block, n_shards=S, use_cache=False)
    per_device = pk.executed_blocks / S
    speedup = base.executed_blocks / per_device
    x = jnp.asarray(rng.standard_normal((64, K)).astype(np.float32))
    fn = jax.jit(lambda xx: ops.sparse_linear(xx, packed=pk))
    np.testing.assert_allclose(np.asarray(fn(x)),
                               np.asarray(ops.sparse_linear(x, packed=base)),
                               rtol=1e-5, atol=1e-5)
    us = timer_us(fn, x)
    return (f"tp_model,decode_fc{K}x{N},tp={S}", us,
            f"tp_speedup={speedup:.2f}x;"
            f"shard_balance={pk.shard_balance:.3f};"
            f"per_device_blocks={per_device:.0f}")


def bench(fast=True):
    """Returns [(name, us_per_call, derived), ...] — shard-balance and
    modeled tensor-parallel speedup rows."""
    del fast  # deterministic layout accounting — no long mode
    return [_balance_row(2), _balance_row(4), _tp_model_row()]


if __name__ == "__main__":
    for row in bench():
        print(row)
