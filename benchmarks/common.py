"""Shared benchmark utilities: tiny conv-net training harness for the
paper-faithful CONV experiments (CIFAR-scale synthetic data)."""
from __future__ import annotations

import time

import jax

from repro.models import convnet as C


def train_convnet(arch=C.VGG_TINY, steps=120, batch=64, lr=5e-2, hard=False,
                  masks=None, params=None, seed=0, penalty_fn=None):
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = C.convnet_init(key, arch)

    def loss_fn(p, b):
        l = C.classify_loss(p, b, arch, masks)
        if penalty_fn is not None:
            l = l + penalty_fn(p)
        return l

    @jax.jit
    def step(p, b):
        g = jax.grad(loss_fn)(p, b)
        return jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g)

    for i in range(steps):
        kb = jax.random.fold_in(key, i + 1)
        imgs, labels = C.synthetic_images(kb, batch, hard=hard)
        params = step(params, (imgs, labels))
    return params


def eval_convnet(params, arch=C.VGG_TINY, hard=False, masks=None, n=512,
                 seed=777):
    imgs, labels = C.synthetic_images(jax.random.PRNGKey(seed), n, hard=hard)
    return float(C.accuracy(params, (imgs, labels), arch, masks))


def timer_us(fn, *args, iters=5):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6
