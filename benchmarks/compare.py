"""Benchmark-regression gate: diff freshly produced ``BENCH_*.json`` files
against the committed baselines and fail when a gated metric regresses.

Gated metrics (parsed from each row's ``derived`` string):

  * any ``*speedup*=<X>x`` ratio — modeled speedups are deterministic
    (latency model at the layout's executed-block count) and gate at the
    strict threshold; packing-throughput and loop speedups are wall-clock
    ratios that swing tens of percent with machine load, so they gate at
    the looser ``--wall-threshold`` — still catching the collapse that
    matters (e.g. the vectorized packer falling back toward the loop
    packer's floor) without flaking on CI noise.
  * effective skipped-FLOP fractions (``flops_saved*``,
    ``flops_skipped_eff``) — exact properties of the packed layout; any
    drop means the packing or reordering algorithm got worse.  Baselines
    below 0.05 are skipped (relative noise on ~zero).
  * memory metrics (``*_mb``: peak working set, HBM bytes moved) — these
    gate LOWER-is-better: deterministic byte accounting of the executed
    path, so a fresh value above ``baseline * (1 + threshold)`` means a
    code change started allocating/moving more (e.g. the implicit conv
    path re-materializing its patch tensor).
  * serving throughput (``*tok_per_s``) — higher-is-better wall-clock
    tokens/s from the continuous-batching engine; gated at the loose
    ``--wall-threshold`` like the other wall ratios (``batch_speedup``,
    the B=8/B=1 decode scaling, is wall-derived too and gates the same
    way).
  * batch occupancy (``mean_occupancy``) — the scheduler's mean busy-slot
    fraction over a *simulated* (virtual-step) workload: fully
    deterministic, so it gates at the strict threshold; a drop means the
    scheduler started stranding slots.
  * shard balance (``shard_balance``) — the tensor-parallel straggler
    factor (max/mean per-shard executed blocks) of the degree-balanced
    column assignment: exact layout accounting, gated LOWER-is-better at
    the strict threshold; growth means ``shard_columns`` stopped
    equalizing per-device work.  (``tp_speedup``, the modeled parallel
    scaling, gates at the loose wall threshold — cross-shard padding
    shifts it with the degree draw.)
  * chaos-harness metrics (``bench_faults``):
    ``degraded_throughput_ratio`` (degraded tok/s over healthy tok/s — a
    wall-clock ratio, gated at ``--wall-threshold``; the bench itself
    additionally enforces the hard 0.8x acceptance floor) and
    ``recovery_steps`` (quarantine eviction to slot re-admission — a
    deterministic scheduler replay, gated LOWER-is-better strict).

A higher-better metric regresses when ``fresh < baseline * (1 -
threshold)`` (default threshold 10%, wall metrics 50%); a lower-is-better
metric (``*_mb``, ``shard_balance``) when ``fresh > baseline * (1 +
threshold)``.  Rows or metrics present in
the baseline but missing from the fresh run also fail — a silently
dropped row is a lost metric, not a pass.  New rows/metrics are reported
and ignored until the baselines are refreshed.

Workflow when a change legitimately shifts the numbers::

    PYTHONPATH=src python -m benchmarks.run --json
    python -m benchmarks.compare --update-baselines   # then commit

Baselines live in ``benchmarks/baselines/``; fresh files are written to the
working directory by ``benchmarks.run --json``.
"""

import argparse
import json
import pathlib
import re
import shutil
import sys

BASELINE_DIR = pathlib.Path(__file__).resolve().parent / "baselines"
FRACTION_KEYS = (
    "flops_saved",
    "flops_saved_exec",
    "flops_skipped_eff",
    "mean_flops_saved",
    "mean_flops_saved_exec",
    "mean_occupancy",
)
FRACTION_FLOOR = 0.05
SPEEDUP_RE = re.compile(r"^([0-9.]+)x$")
# wall-clock-derived ratios: gated at --wall-threshold, not --threshold
WALL_KEYS = (
    "loop_speedup",
    "artifact_warm_speedup",
    "batch_speedup",
    "tp_speedup",
    "degraded_throughput_ratio",
)
WALL_ROW_PREFIXES = ("pack_vectorized", "coldstart")
# lower-is-better byte metrics (deterministic accounting, no wall noise)
MEMORY_SUFFIX = "_mb"
# lower-is-better metrics gated strict: the sharded straggler factor
# max/mean executed blocks per shard (deterministic layout accounting)
# and the chaos harness's quarantine-to-readmission step count
# (deterministic scheduler replay)
LOWER_BETTER_KEYS = ("shard_balance", "recovery_steps")
# higher-is-better wall-clock throughput (serving engine tokens/s)
THROUGHPUT_SUFFIX = "tok_per_s"


def is_wall_metric(key):
    row, _, metric = key.rpartition(":")
    return (metric in WALL_KEYS or metric.endswith(THROUGHPUT_SUFFIX)
            or row.startswith(WALL_ROW_PREFIXES))


def is_lower_better(key):
    metric = key.rsplit(":", 1)[-1]
    return metric.endswith(MEMORY_SUFFIX) or metric in LOWER_BETTER_KEYS


def metrics_from(payload):
    """{'row:key': value} for every gated metric of one BENCH payload."""
    out = {}
    for row in payload.get("rows", []):
        pairs = [kv.split("=", 1) for kv in row["derived"].split(";") if "=" in kv]
        for key, val in pairs:
            ratio = SPEEDUP_RE.match(val)
            if "speedup" in key and ratio:
                out[f"{row['name']}:{key}"] = float(ratio.group(1))
            elif (
                key in FRACTION_KEYS
                or key in LOWER_BETTER_KEYS
                or key in WALL_KEYS
                or key.endswith(MEMORY_SUFFIX)
                or key.endswith(THROUGHPUT_SUFFIX)
            ):
                out[f"{row['name']}:{key}"] = float(val)
    return out


def load_metrics(path):
    """Parse one BENCH json into gated metrics; (metrics, error_line).

    Any way the file can be bad — unreadable, invalid JSON, rows missing
    the ``name``/``derived`` keys — comes back as a one-line error string
    instead of a traceback, so a corrupted or hand-edited baseline fails
    the gate with an actionable message rather than a stack dump.
    """
    try:
        return metrics_from(json.loads(path.read_text())), None
    except OSError as e:
        return None, f"{path.name} unreadable ({e.strerror or e})"
    except (json.JSONDecodeError, KeyError, TypeError, AttributeError) as e:
        return None, f"{path.name} corrupt ({type(e).__name__}: {e})"


def compare_one(name, base_path, fresh_path, threshold, wall_threshold):
    """Returns (failures, notes) for one benchmark file pair."""
    failures, notes = [], []
    if not fresh_path.exists():
        msg = (
            f"{name}: fresh {fresh_path} missing — run its suite "
            "(benchmarks.run --json), or if the bench was removed/renamed "
            "drop the stale baseline via --update-baselines"
        )
        return [msg], []
    base, err = load_metrics(base_path)
    if err:
        return [f"{name}: baseline {err}; re-promote with --update-baselines"], []
    fresh, err = load_metrics(fresh_path)
    if err:
        return [f"{name}: fresh {err}; re-run benchmarks.run --json"], []
    for key, b in sorted(base.items()):
        if key not in fresh:
            failures.append(
                f"{name}: metric {key!r} vanished (baseline {b:.2f}); "
                "refresh with --update-baselines if intentional"
            )
            continue
        f = fresh[key]
        is_fraction = key.rsplit(":", 1)[-1] in FRACTION_KEYS
        if is_fraction and b < FRACTION_FLOOR:
            continue
        allowed = wall_threshold if is_wall_metric(key) else threshold
        if is_lower_better(key):
            if f > b * (1 + allowed):
                failures.append(
                    f"{name}: {key} grew {b:.2f} -> {f:.2f} "
                    f"({(f / b - 1) * 100:.0f}% > {allowed * 100:.0f}% "
                    "allowed; this metric gates lower-is-better)"
                )
        elif f < b * (1 - allowed):
            failures.append(
                f"{name}: {key} regressed {b:.2f} -> {f:.2f} "
                f"({(1 - f / b) * 100:.0f}% > {allowed * 100:.0f}% allowed)"
            )
    for key in sorted(set(fresh) - set(base)):
        notes.append(f"{name}: new metric {key} = {fresh[key]:.2f} (not gated)")
    return failures, notes


def update_baselines(fresh_dir):
    BASELINE_DIR.mkdir(exist_ok=True)
    copied = []
    for path in sorted(fresh_dir.glob("BENCH_*.json")):
        shutil.copy(path, BASELINE_DIR / path.name)
        copied.append(path.name)
    if not copied:
        raise SystemExit(f"no BENCH_*.json in {fresh_dir} to promote")
    print(f"promoted {len(copied)} baseline(s): {', '.join(copied)}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="allowed relative regression before failing (default 0.10)",
    )
    ap.add_argument(
        "--wall-threshold",
        type=float,
        default=0.50,
        help="allowed regression for wall-clock-derived ratios (default 0.50)",
    )
    ap.add_argument(
        "--fresh-dir",
        type=pathlib.Path,
        default=pathlib.Path("."),
        help="directory holding the freshly produced BENCH_*.json",
    )
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="copy fresh BENCH_*.json over benchmarks/baselines/ and exit",
    )
    args = ap.parse_args(argv)
    if args.update_baselines:
        update_baselines(args.fresh_dir)
        return 0
    baselines = sorted(BASELINE_DIR.glob("BENCH_*.json"))
    if not baselines:
        raise SystemExit(f"no baselines committed under {BASELINE_DIR}")
    failures, notes = [], []
    for base_path in baselines:
        fail, note = compare_one(
            base_path.stem,
            base_path,
            args.fresh_dir / base_path.name,
            args.threshold,
            args.wall_threshold,
        )
        failures += fail
        notes += note
    baseline_names = {p.name for p in baselines}
    for path in sorted(args.fresh_dir.glob("BENCH_*.json")):
        if path.name not in baseline_names:
            notes.append(
                f"{path.stem}: fresh file has no committed baseline "
                "(not gated); promote with --update-baselines"
            )
    for line in notes:
        print(f"note: {line}")
    if failures:
        for line in failures:
            print(f"FAIL: {line}", file=sys.stderr)
        return 1
    gated = sum(
        len(metrics_from(json.loads(p.read_text()))) for p in baselines
    )
    print(
        f"benchmark gate passed: {gated} metric(s) across "
        f"{len(baselines)} file(s) within {args.threshold * 100:.0f}%"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
