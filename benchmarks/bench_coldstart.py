"""Cold-start vs warm artifact-store start for the §4.3 compile pipeline.

The ROADMAP's "kill the cold start" item: a replica restarting under load
should NOT repay the mask scan + per-layer BCS packing when nothing about
the model changed.  This bench measures exactly that hand-off on the smoke
yi-9b LM at 75% block sparsity:

  * cold  — empty artifact store: ``compile_model`` scans the masks, packs
    every layer, then publishes the artifact (digest-keyed dir, per-file
    checksums, atomic rename).
  * warm  — same call against the now-populated store: digest match ->
    checksum verify -> layout validation -> graft, no packing at all.

``artifact_warm_speedup`` is the gated headline (wall-clock ratio, so it
rides the loose ``--wall-threshold``); ``artifact_mb`` gates the on-disk
artifact size lower-is-better (deterministic byte accounting — growth
means the serialized layout format got fatter).  The pack cache is cleared
before every measurement so neither side hides behind the in-process
content cache."""
import shutil
import tempfile
import time

import jax

from repro import configs
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.models import transformer as T
from repro.serve.compile import compile_model
from repro.train.trainer import apply_masks

SPEC = [(r"(attn/w[qkvo]|ffn/(gate|up|down))/w",
         RW.SchemeChoice("block", (16, 16)))]


def _store_bytes(store):
    return sum(p.stat().st_size for p in store.rglob("*") if p.is_file())


def bench(fast=True):
    import pathlib

    rows = []
    arch = "yi-9b"
    cfg = configs.get(arch, smoke=True)
    zero_frac = 0.75
    warm_iters = 3 if fast else 8
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    masks = RW.random_block_masks(params, SPEC, (16, 16),
                                  keep_prob=1.0 - zero_frac)
    pm = apply_masks(params, masks)

    store = pathlib.Path(tempfile.mkdtemp(prefix="bench_coldstart_"))
    try:
        ops.clear_pack_cache()
        t0 = time.perf_counter()
        exec_cold, report = compile_model(pm, masks, SPEC,
                                          artifact_dir=store)
        t_cold = time.perf_counter() - t0

        t_warm = float("inf")
        for _ in range(warm_iters):
            ops.clear_pack_cache()
            t0 = time.perf_counter()
            exec_warm, _ = compile_model(pm, masks, SPEC,
                                         artifact_dir=store)
            t_warm = min(t_warm, time.perf_counter() - t0)

        packed = [r for r in report if r["packed"]]
        mb = _store_bytes(store) / 2**20
        rows.append((f"coldstart,{arch},zf{zero_frac:.2f}", t_warm * 1e6,
                     f"artifact_warm_speedup={t_cold / t_warm:.2f}x;"
                     f"pack_cold_us={t_cold * 1e6:.0f};"
                     f"warm_load_us={t_warm * 1e6:.0f};"
                     f"packed_layers={len(packed)};"
                     f"artifact_mb={mb:.2f}"))
    finally:
        shutil.rmtree(store, ignore_errors=True)
    return rows
