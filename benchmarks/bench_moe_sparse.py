"""Batched sparse MoE expert GEMMs vs the dense masked einsum path.

Two row families per expert count:

  parity rows  — smoke-dim full ``moe()`` through ``compile_model`` +
    ``kernels.ops.sparse_expert_linear`` (the vmapped BCS kernel) vs the
    dense masked einsum: measured wall time (interpret-mode Pallas, so
    only the correctness ``max_err`` is meaningful) and the packed
    layers' effective skipped-FLOP fraction.

  modeled rows — per-expert packs at serving-scale GEMM dims
    (D=1024, F=4096, MXU-sized (128,128) blocks): dense vs batched sparse
    expert latency from ``core.latency_model`` at the layout's
    executed-block count, with and without row reordering.  Wall-clock on
    TPU is not measurable in this container, so the modeled number is the
    headline — the same convention as ``bench_kernel``.

Emitted rows land in BENCH_moe_sparse.json under ``run.py --json``."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reweighted as RW
from repro.core.latency_model import matmul_latency
from repro.kernels import ops
from repro.models.moe import moe, moe_init
from repro.serve.compile import compile_model
from repro.train.trainer import apply_masks

MOE_SPEC = [(r"(gate|up|down)/w", RW.SchemeChoice("block", (16, 16)))]


def _parity_row(E, zero_frac, top_k=2):
    D, F = 64, 128
    params = moe_init(jax.random.PRNGKey(0), D, F, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, D), jnp.float32)
    masks = RW.random_block_masks(params, MOE_SPEC, (16, 16),
                                  keep_prob=1.0 - zero_frac)
    masked = apply_masks(params, masks)
    exec_params, report = compile_model(masked, masks, MOE_SPEC)
    packed = [r for r in report if r["packed"]]
    t0 = time.perf_counter()
    out_d, _ = jax.block_until_ready(moe(masked, x, top_k=top_k, group=64))
    t_dense = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_s, _ = jax.block_until_ready(
        moe(exec_params, x, top_k=top_k, group=64))
    t_sparse = time.perf_counter() - t0
    err = float(jnp.max(jnp.abs(out_d - out_s)))
    saved = float(np.mean([r["flops_saved"] for r in packed])) if packed \
        else 0.0
    return (f"moe,E{E},zf{zero_frac:.2f},parity", t_sparse * 1e6,
            f"wall_dense_us={t_dense * 1e6:.0f};packed_layers={len(packed)};"
            f"mean_flops_saved={saved:.2f};max_err={err:.1e}")


def modeled_expert_us(E, zero_frac, tokens_per_expert=1024, seed=0):
    """Modeled dense vs batched-sparse expert-GEMM latency at serving dims
    (D=1024, F=4096, MXU (128,128) blocks): the executed-block count comes
    from a real pack of a weight at those dims and this sparsity.  Shared
    by ``bench_moe_sparse`` and the MoE row of ``bench_e2e_sparse``.

    Returns (us_dense, us_reordered, us_unreordered, plain, reord)."""
    D, F, blk = 1024, 4096, (128, 128)
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((D, F)).astype(np.float32)
    keep = rng.random((D // blk[0], F // blk[1])) > zero_frac
    mask = np.repeat(np.repeat(keep, blk[0], 0), blk[1], 1)
    mask = mask.astype(np.float32)
    plain = ops.pack(w, mask, blk)
    reord = ops.pack(w, mask, blk, reorder=True, n_bins=8)

    def us(layout):
        comp = (layout.Kb * layout.Nb) / max(layout.executed_blocks, 1)
        return E * matmul_latency(tokens_per_expert, D, F, scheme="block",
                                  block=blk, compression=comp) * 1e6

    us_dense = E * matmul_latency(tokens_per_expert, D, F,
                                  scheme="none") * 1e6
    return us_dense, us(reord), us(plain), plain, reord


def _modeled_row(E, zero_frac):
    us_dense, us_reord, us_plain, plain, reord = modeled_expert_us(
        E, zero_frac)
    return (f"moe,E{E},zf{zero_frac:.2f},modeled", us_reord,
            f"dense_einsum_us={us_dense:.1f};"
            f"speedup_vs_dense={us_dense / us_reord:.2f}x;"
            f"unreordered_us={us_plain:.1f};"
            f"flops_saved={reord.flops_saved:.2f};"
            f"L={plain.L_max}->{reord.L_effective:.2f}")


def bench(fast=True):
    rows = []
    for E in ((4, 8) if fast else (4, 8, 16)):
        for zero_frac in ((0.75,) if fast else (0.5, 0.75, 0.875)):
            rows.append(_parity_row(E, zero_frac))
            rows.append(_modeled_row(E, zero_frac))
    return rows
