"""Continuous-batching serving benchmark: decode throughput and batch
occupancy of ``serve.engine.ServingEngine`` on the sparse-compiled smoke LM.

Two row groups, both on packed (BCS) params in interpret mode:

* ``serving,B{N}`` — saturated closed-loop decode at N slots, plus a
  ``serving,scaling`` row with ``batch_speedup`` = (B=8 tok/s)/(B=1
  tok/s).  This is THE tentpole metric: one batched launch amortizes the
  packed weights over B requests, so per-launch overhead (dominant in
  interpret mode, HBM weight streaming on real hardware) stops being paid
  per token.  The acceptance floor is 3x; the committed baseline gates it
  (wall-clock, so at the loose wall threshold).
* ``serving,rate{R}`` — open-loop arrival sweep at 8 slots: tokens/s and
  the *deterministic* mean batch occupancy (strictly gated — a scheduler
  change that strands slots shows up here, no wall-clock noise).

Emitted to BENCH_serving.json under ``run.py --json`` and gated by
``benchmarks.compare`` like the other suites (``*_tok_per_s`` and
``batch_speedup`` at the wall threshold, ``mean_occupancy`` strict).
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core import reweighted as RW
from repro.launch.serve import SPARSE_SPEC
from repro.models import transformer as T
from repro.serve.compile import CompileSpec, compile_model
from repro.serve.engine import ServingEngine
from repro.train.trainer import apply_masks

ARCH = "yi-9b"
SEQ_CAP = 48


def _packed_smoke_lm():
    cfg = configs.get(ARCH, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None, rate=0.6)
    params = apply_masks(params, masks)
    params, _ = compile_model(params, masks, SPARSE_SPEC,
                              spec=CompileSpec(keep_dense=False))
    return params, cfg


def _prompts(cfg, n, prompt_len):
    rng = np.random.RandomState(0)
    # two length buckets: exercises the bucketed prefill/slot-write caches
    lens = (prompt_len, max(2, prompt_len // 2))
    return [rng.randint(1, cfg.vocab, size=lens[i % 2]).tolist()
            for i in range(n)]


def _run(params, cfg, prompts, new_tokens, n_slots, arrivals=None):
    """One engine run; returns (wall_s, engine).  A same-shaped warm-up
    engine runs first so the timed run measures steady-state serving, not
    tracing."""
    for timed in (False, True):
        eng = ServingEngine(params, cfg, n_slots=n_slots, seq_cap=SEQ_CAP)
        for i, p in enumerate(prompts):
            eng.submit(p, new_tokens,
                       arrival=arrivals[i] if arrivals else 0)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        if timed:
            return dt, eng


def bench(fast=True):
    params, cfg = _packed_smoke_lm()
    # enough decode steps that the B=8 run's 2*8 serial prefills stop
    # dominating the wall clock (pure decode scales ~6x at B=8; short
    # requests would hide that behind prefill cost)
    new_tokens = 24 if fast else 32
    prompt_len = 16
    rows = []

    # -- saturated decode scaling: B=1 vs B=8, same per-request work ------
    tok_per_s = {}
    for n_slots in (1, 8):
        prompts = _prompts(cfg, 2 * n_slots, prompt_len)
        dt, eng = _run(params, cfg, prompts, new_tokens, n_slots)
        tps = eng.stats["tokens"] / dt
        tok_per_s[n_slots] = tps
        rows.append((f"serving,B{n_slots}", dt / eng.stats["steps"] * 1e6,
                     f"tok_per_s={tps:.1f};"
                     f"mean_occupancy={eng.mean_occupancy():.2f};"
                     f"requests={eng.stats['finished']};"
                     f"steps={eng.stats['steps']}"))
    speedup = tok_per_s[8] / tok_per_s[1]
    rows.append(("serving,scaling", 0.0,
                 f"batch_speedup={speedup:.2f}x;"
                 f"b1_tok_per_s={tok_per_s[1]:.1f};"
                 f"b8_tok_per_s={tok_per_s[8]:.1f};"
                 "acceptance_floor=3x"))

    # -- open-loop arrival sweep at 8 slots -------------------------------
    n_req = 12 if fast else 32
    for rate in (0.25, 1.0, 4.0):
        prompts = _prompts(cfg, n_req, prompt_len)
        arrivals = [int(i / rate) for i in range(n_req)]
        dt, eng = _run(params, cfg, prompts, new_tokens, 8, arrivals)
        rows.append((f"serving,rate{rate:g}", dt / eng.stats["steps"] * 1e6,
                     f"tok_per_s={eng.stats['tokens'] / dt:.1f};"
                     f"mean_occupancy={eng.mean_occupancy():.2f};"
                     f"admitted={eng.stats['admitted']};"
                     f"evicted={eng.stats['evicted']}"))
    return rows
