"""Chaos benchmark: serving throughput under injected faults plus the
deterministic recovery bound, all driven by the seeded injectors in
``repro.testing.faults``.

Three rows on the sparse-compiled smoke LM (packed, ``keep_dense=True``
so the degrade path has its masked-dense fallback):

* ``faults,healthy`` — baseline closed-loop decode tok/s (same engine
  shape as ``bench_serving``).
* ``faults,degraded`` — the SAME workload after a seeded bit-flip
  corrupts one packed layout: the engine degrades that layer to
  masked-dense at construction and keeps serving.
  ``degraded_throughput_ratio`` (degraded tok/s / healthy tok/s) is the
  acceptance metric: the floor is 0.8x (enforced here AND gated at the
  wall threshold by ``benchmarks.compare`` against the committed
  baseline) — degraded mode must cost bounded throughput, never an
  outage.
* ``faults,recovery`` — deterministic quarantine recovery:
  ``recovery_steps`` counts engine steps from a NaN-poisoned slot's
  quarantine eviction to the freed slot's re-admission from the queue
  (expected 1; gated LOWER-is-better at the strict threshold — growth
  means eviction stopped freeing capacity promptly).

Emitted to BENCH_faults.json under ``run.py --json`` and gated by
``benchmarks.compare`` like the other suites.
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core import reweighted as RW
from repro.launch.serve import SPARSE_SPEC
from repro.models import transformer as T
from repro.serve.compile import CompileSpec, compile_model
from repro.serve.engine import ServingEngine
from repro.testing import faults as F
from repro.train.trainer import apply_masks

ARCH = "yi-9b"
SEQ_CAP = 48
DEGRADED_FLOOR = 0.8    # acceptance: degraded tok/s >= 0.8x healthy


def _packed_smoke_lm():
    cfg = configs.get(ARCH, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    masks = RW.magnitude_block_masks(params, SPARSE_SPEC, None, rate=0.6)
    params = apply_masks(params, masks)
    params, _ = compile_model(params, masks, SPARSE_SPEC,
                              spec=CompileSpec(keep_dense=True))
    return params, cfg


def _prompts(cfg, n, prompt_len=16):
    rng = np.random.RandomState(0)
    lens = (prompt_len, max(2, prompt_len // 2))
    return [rng.randint(1, cfg.vocab, size=lens[i % 2]).tolist()
            for i in range(n)]


def _throughput(params, cfg, prompts, new_tokens, n_slots=4):
    """(wall_s, engine) for one closed-loop run; an untimed warm-up run
    first so the timed pass measures steady-state serving, not tracing."""
    for timed in (False, True):
        eng = ServingEngine(params, cfg, n_slots=n_slots, seq_cap=SEQ_CAP)
        for p in prompts:
            eng.submit(p, new_tokens)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        if timed:
            return dt, eng


def bench(fast=True):
    params, cfg = _packed_smoke_lm()
    new_tokens = 24 if fast else 32
    n_req = 8 if fast else 16
    prompts = _prompts(cfg, n_req)
    rows = []

    # -- healthy baseline -------------------------------------------------
    dt_h, eng_h = _throughput(params, cfg, prompts, new_tokens)
    healthy = eng_h.stats["tokens"] / dt_h
    rows.append(("faults,healthy", dt_h / eng_h.stats["steps"] * 1e6,
                 f"tok_per_s={healthy:.1f};"
                 f"requests={eng_h.stats['finished']};"
                 f"steps={eng_h.stats['steps']}"))

    # -- degraded mode: seeded bit-flip -> masked-dense fallback ----------
    bad, rec = F.bitflip_packed_leaf(params, seed=0)
    dt_d, eng_d = _throughput(bad, cfg, prompts, new_tokens)
    if eng_d.stats["degraded_layers"] < 1:
        raise RuntimeError("bit-flip was not detected: no layer degraded")
    if eng_d.stats["finished"] != eng_h.stats["finished"]:
        raise RuntimeError("degraded engine dropped requests")
    degraded = eng_d.stats["tokens"] / dt_d
    ratio = degraded / healthy
    if ratio < DEGRADED_FLOOR:
        raise RuntimeError(
            f"degraded throughput ratio {ratio:.2f} below the "
            f"{DEGRADED_FLOOR:g}x acceptance floor ({degraded:.1f} vs "
            f"{healthy:.1f} tok/s)")
    rows.append(("faults,degraded", dt_d / eng_d.stats["steps"] * 1e6,
                 f"tok_per_s={degraded:.1f};"
                 f"degraded_throughput_ratio={ratio:.2f};"
                 f"degraded_layers={eng_d.stats['degraded_layers']};"
                 f"fault={rec.target};"
                 f"acceptance_floor={DEGRADED_FLOOR:g}x"))

    # -- quarantine recovery bound (deterministic, no wall clock) ---------
    eng = ServingEngine(params, cfg, n_slots=2, seq_cap=SEQ_CAP)
    rids = [eng.submit(p, new_tokens) for p in prompts[:3]]
    eng.step()                                   # admit the first two
    victim = rids[1]
    F.nan_slot(eng, eng.requests[victim].slot)
    while eng.requests[victim].status != "quarantined":
        eng.step()
    q_step = eng.stats["steps"]
    while eng.requests[rids[2]].status == "queued":
        eng.step()
    recovery = eng.stats["steps"] - q_step
    eng.run()
    rows.append(("faults,recovery", 0.0,
                 f"recovery_steps={recovery};"
                 f"quarantined={eng.stats['quarantined']};"
                 f"finished={eng.stats['finished']}"))
    return rows
