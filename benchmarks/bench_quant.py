"""Quantized (int8) sparse serving vs the float packs — the value path of
``CompileSpec(value_dtype="int8")`` measured on the paper's two fixture
families.

Three rows, one per kernel family the quantized layouts feed:

  * ``quant_conv`` — a VGG-scale 3x3 conv under block-punched pruning,
    packed at fp32 and at int8 ("block" scale granularity), served through
    ``ops.sparse_conv2d``.
  * ``quant_pattern`` — the same conv under a 4-of-9 pattern +
    connectivity mask, tap-lowered and quantized per-filter ("out" — the
    granularity ``compile_model`` always uses for tap layouts), served
    through ``ops.sparse_conv2d_pattern``.
  * ``quant_moe_fc`` — an MoE-expert-shaped FC GEMM under block pruning,
    served through ``ops.sparse_linear``.
  * ``quant_decode_fc`` — a decode-shaped LM projection (small M, 4k x 4k
    weight, MXU-sized blocks): the memory-bound regime where the weight
    read dominates the roofline, so the int8 pick wins MODELED latency
    too — the exact shape class both mappers flip to int8 on
    (``quant_speedup`` > 1 here; the small fixtures above are
    step-overhead-bound, so their modeled latency barely moves and the
    mappers correctly keep float values).

Each row reports the modeled latency of the int8 pick next to the float
pick (``quant_speedup`` — ``matmul_latency(value_bytes=1)`` vs the
default, the exact pricing both mappers choose precision by), the REAL
packed-layout bytes of both packs (``w_fp32_mb`` / ``w_int8_mb``,
deterministic accounting of values + indices + scales) and their ratio
(``bytes_speedup`` — asserted >= 1.5x on the block-layout rows and
regression-gated via the baseline: int8 must actually shrink the
artifact, scales included; the tap row reports ungated, its 4-byte
per-value tap ids cap the ratio below the block layouts'), and
the kernel's parity error against the DEQUANTIZED dense oracle
(``max_err`` — ``layout.to_dense()`` through the dense reference; the
kernels dequantize before the fp32 accumulation, so this is a tight
float-roundoff bound, not a quantization-error bound).  Emitted rows land
in BENCH_quant.json under ``run.py --json``."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core.latency_model import conv_as_gemm, im2col_x_frac, \
    matmul_latency
from repro.kernels import ops

MIN_BYTES_SPEEDUP = 1.5


def _layout_mb(layout):
    return ops._entry_bytes(layout) / 1e6


def _derived(t_fp, t_q, mb_fp, mb_q, err, gate=True):
    if gate:
        assert mb_fp / mb_q >= MIN_BYTES_SPEEDUP, (
            f"int8 pack shrinks weight bytes only {mb_fp / mb_q:.2f}x "
            f"(< {MIN_BYTES_SPEEDUP}x): scale leaves are eating the win")
    return (f"quant_speedup={t_fp / t_q:.2f}x;"
            f"bytes_speedup={mb_fp / mb_q:.2f}x;"
            f"w_fp32_mb={mb_fp:.3f};w_int8_mb={mb_q:.3f};"
            f"max_err={err:.1e}")


def _conv_row(P=128, Q=128, feat=14, kernel_block=(8, 8), rate=0.6):
    kh = kw = 3
    w = jax.random.normal(jax.random.PRNGKey(0), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, kernel_block, rate=rate)
    wm = w * mask
    gemm_block, why = BCS.conv_gemm_block(kernel_block, w.shape)
    assert gemm_block is not None, why
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    conv = (kh, kw, Q)
    fp = ops.pack(wl, ml, gemm_block, reorder=True, n_bins=4, conv=conv)
    q8 = ops.pack(wl, ml, gemm_block, reorder=True, n_bins=4, conv=conv,
                  value_dtype="int8")
    M, K, N = conv_as_gemm(feat, Q, P, kh, kw)
    comp = (fp.Kb * fp.Nb) / max(fp.executed_blocks, 1)
    lat = lambda vb: matmul_latency(
        M, K, N, scheme="block_punched", block=gemm_block,
        compression=comp, value_bytes=vb, x_frac=im2col_x_frac(kh * kw))
    t_fp, t_q = lat(None), lat(1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d(x, q8, kh=kh, kw=kw)
    ref_w = jnp.asarray(q8.to_dense()).reshape(kh, kw, Q, P)
    y_ref = jax.lax.conv_general_dilated(
        x, ref_w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    return (f"quant_conv,{P}x{Q}x3x3,blk{kernel_block[0]}x{kernel_block[1]}",
            t_q * 1e6,
            _derived(t_fp, t_q, _layout_mb(fp), _layout_mb(q8), err))


def _pattern_row(P=128, Q=128, feat=14, connectivity=0.5):
    kh = kw = 3
    w = jax.random.normal(jax.random.PRNGKey(2), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.pattern_mask(w, connectivity_rate=connectivity)
    wm = w * mask
    fp = ops.pack_taps(wm, mask)
    q8 = ops.pack_taps(wm, mask, value_dtype="int8",
                       scale_granularity="out")
    M, K, N = conv_as_gemm(feat, Q, P, kh, kw)
    frac = 1.0 - fp.flops_saved
    lat = lambda vb: matmul_latency(
        M, K, N, scheme="pattern", compression=1 / frac, value_bytes=vb,
        executed_frac=frac, x_frac=im2col_x_frac(kh * kw))
    t_fp, t_q = lat(None), lat(1)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d_pattern(x, q8, kh=kh, kw=kw)
    ref_w = jnp.asarray(q8.to_dense()).reshape(kh, kw, Q, P)
    y_ref = jax.lax.conv_general_dilated(
        x, ref_w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    return (f"quant_pattern,{P}x{Q}x3x3,conn{connectivity}", t_q * 1e6,
            _derived(t_fp, t_q, _layout_mb(fp), _layout_mb(q8), err,
                     gate=False))


def _whole_block_mask(key, shape, block, keep):
    """Keep-mask that kills WHOLE (bk, bn) blocks — the structured
    collapse the BCS kernels actually skip (``block_mask`` prunes
    rows/cols inside blocks, which leaves every block alive)."""
    kb = jax.random.uniform(key, (shape[0] // block[0],
                                  shape[1] // block[1])) < keep
    return jnp.kron(kb.astype(jnp.float32),
                    jnp.ones(block, jnp.float32))


def _moe_fc_row(M=64, K=512, N=1024, block=(16, 16), keep=0.4):
    w = jax.random.normal(jax.random.PRNGKey(4), (K, N), jnp.float32) * 0.1
    mask = _whole_block_mask(jax.random.PRNGKey(14), (K, N), block, keep)
    wm = w * mask
    fp = ops.pack(wm, mask, block, reorder=True, n_bins=4)
    q8 = ops.pack(wm, mask, block, reorder=True, n_bins=4,
                  value_dtype="int8")
    comp = (fp.Kb * fp.Nb) / max(fp.executed_blocks, 1)
    lat = lambda vb: matmul_latency(M, K, N, scheme="block", block=block,
                                    compression=comp, value_bytes=vb)
    t_fp, t_q = lat(None), lat(1)
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K), jnp.float32)
    y = ops.sparse_linear(x, packed=q8)
    y_ref = x @ jnp.asarray(q8.to_dense())
    err = float(jnp.max(jnp.abs(y - y_ref)))
    return (f"quant_moe_fc,{K}x{N},blk{block[0]}x{block[1]}", t_q * 1e6,
            _derived(t_fp, t_q, _layout_mb(fp), _layout_mb(q8), err))


def _decode_fc_row(M=256, K=4096, N=4096, block=(128, 128), keep=0.125):
    w = jax.random.normal(jax.random.PRNGKey(6), (K, N), jnp.float32) * 0.1
    mask = _whole_block_mask(jax.random.PRNGKey(16), (K, N), block, keep)
    wm = w * mask
    fp = ops.pack(wm, mask, block, reorder=True, n_bins=4)
    q8 = ops.pack(wm, mask, block, reorder=True, n_bins=4,
                  value_dtype="int8")
    comp = (fp.Kb * fp.Nb) / max(fp.executed_blocks, 1)
    lat = lambda vb: matmul_latency(M, K, N, scheme="block", block=block,
                                    compression=comp, value_bytes=vb)
    t_fp, t_q = lat(None), lat(1)
    assert t_q < t_fp, (
        f"int8 must win modeled latency on the decode-shaped FC "
        f"(fp {t_fp * 1e6:.1f}us vs int8 {t_q * 1e6:.1f}us)")
    x = jax.random.normal(jax.random.PRNGKey(7), (M, K), jnp.float32)
    y = ops.sparse_linear(x, packed=q8)
    y_ref = x @ jnp.asarray(q8.to_dense())
    err = float(jnp.max(jnp.abs(y - y_ref)))
    return (f"quant_decode_fc,{K}x{N},blk{block[0]}x{block[1]}", t_q * 1e6,
            _derived(t_fp, t_q, _layout_mb(fp), _layout_mb(q8), err))


def bench(fast=True):
    """Returns [(name, us_per_call, derived), ...] — modeled int8 latency
    per row, with the fp-vs-int8 speedup/bytes/parity metrics in
    ``derived``."""
    del fast  # deterministic byte/latency accounting — no long mode
    return [_conv_row(), _pattern_row(), _moe_fc_row(), _decode_fc_row()]


if __name__ == "__main__":
    for row in bench():
        print(row)
