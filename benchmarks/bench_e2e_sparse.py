"""End-to-end sparse serving benchmark: the §4.3 compiler path measured at
the WHOLE-MODEL level, not just one GEMM.

For a smoke LM at several block densities:
  - compile (pack) time through ``compile_model`` — cold and cached,
  - prefill + fused-scan decode latency on packed params,
  - the eager per-token Python decode loop for comparison (what the fused
    ``lax.scan`` loop in serve.engine replaces),
  - a MoE row: the three expert GEMMs through the batched sparse path
    (``kernels.ops.sparse_expert_linear``) vs the dense masked einsum,
    with the modeled serving-dim latency as the headline (interpret-mode
    Pallas wall time is not meaningful; same convention as bench_kernel),
  - conv rows: the whole VGG_TINY net through the im2col conv producer at
    two kernel-block sizes (the Fig 5/7 sweep axis), reporting the
    *executed-L* savings of the padded layout next to the raw zero
    fraction it replaces (layer-level sweeps live in bench_conv_sparse).
Emitted rows land in BENCH_e2e_sparse.json under ``run.py --json`` so later
PRs have a perf trajectory to compare against."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import reweighted as RW
from repro.kernels import ops
from repro.models import convnet as CN
from repro.models import transformer as T
from repro.serve.compile import compile_model
from repro.serve.engine import generate, generate_python
from repro.train.trainer import apply_masks
from repro.data.pipeline import synthetic_batch

SPEC = [(r"(attn/w[qkvo]|ffn/(gate|up|down))/w",
         RW.SchemeChoice("block", (16, 16)))]

MOE_SPEC = [(r"(attn/w[qkvo]|moe/(gate|up|down))/w",
             RW.SchemeChoice("block", (16, 16)))]


def _block_masks(params, zero_frac, block=(16, 16)):
    return RW.random_block_masks(params, SPEC, block,
                                 keep_prob=1.0 - zero_frac)


def _timed(fn, iters):
    fn()                               # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters


def _moe_rows(fast=True):
    """Packed expert execution vs the dense masked einsum at >=70% block
    sparsity: correctness + e2e generate on the smoke mixtral, modeled
    expert-GEMM latency at serving dims (where the uniform-padded,
    row-reordered layout's executed-block count decides the win)."""
    rows = []
    arch = "mixtral-8x7b"
    cfg = configs.get(arch, smoke=True)
    batch, prompt, new = 4, 32, 8
    iters = 1 if fast else 3
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = synthetic_batch(0, 0, batch, prompt, cfg.vocab)["tokens"]
    zero_frac = 0.75
    masks = RW.random_block_masks(params, MOE_SPEC, (16, 16),
                                  keep_prob=1.0 - zero_frac)
    pm = apply_masks(params, masks)
    exec_params, report = compile_model(pm, masks, MOE_SPEC)
    moe_packed = [r for r in report if r["packed"] and "/moe/" in r["path"]]
    t_dense = _timed(lambda: generate(pm, cfg, toks, new), iters)
    t_sparse = _timed(lambda: generate(exec_params, cfg, toks, new), iters)
    saved = (sum(r["flops_saved"] for r in moe_packed) / len(moe_packed)
             if moe_packed else 0.0)

    # modeled expert GEMMs at serving dims (shared helper — see
    # benchmarks.bench_moe_sparse.modeled_expert_us)
    from benchmarks.bench_moe_sparse import modeled_expert_us
    us_dense, us_sparse, _, _, _ = modeled_expert_us(cfg.n_experts,
                                                     zero_frac)
    rows.append((f"e2e,{arch},moe,zf{zero_frac:.2f}", us_sparse,
                 f"dense_einsum_us={us_dense:.1f};"
                 f"modeled_speedup={us_dense / us_sparse:.2f}x;"
                 f"moe_packed_layers={len(moe_packed)};"
                 f"mean_flops_saved={saved:.2f};"
                 f"wall_sparse_interp_us={t_sparse * 1e6:.0f};"
                 f"wall_dense_us={t_dense * 1e6:.0f}"))
    return rows


CONV_SPEC_TMPL = r"(^|/)(c|pw|dw)\d+/w"


def _conv_rows(fast=True):
    """Whole-convnet sparse execution at two kernel-block sizes: the Fig 5/7
    sweep reported as *executed-L* savings (what the kernel actually skips
    under the padded layout) instead of the raw zero fraction."""
    rows = []
    arch = CN.VGG_TINY
    params = CN.convnet_init(jax.random.PRNGKey(0), arch, dtype=jnp.float32)
    x, _ = CN.synthetic_images(jax.random.PRNGKey(1), 4 if fast else 16)
    for kb in ((4, 4), (8, 8)):
        spec = [(CONV_SPEC_TMPL, RW.SchemeChoice("block_punched", kb))]
        masks = RW.punched_conv_masks(params, spec, kb, rate=0.6)
        pm = apply_masks(params, masks)
        t0 = time.perf_counter()
        exec_params, report = compile_model(pm, masks, spec)
        t_pack = time.perf_counter() - t0
        packed = [r for r in report if r["packed"]]
        jax.block_until_ready(CN.convnet_apply(pm, x, arch))
        t0 = time.perf_counter()
        jax.block_until_ready(CN.convnet_apply(pm, x, arch))
        t_dense = time.perf_counter() - t0
        jax.block_until_ready(CN.convnet_apply(exec_params, x, arch))
        t0 = time.perf_counter()
        jax.block_until_ready(CN.convnet_apply(exec_params, x, arch))
        t_sparse = time.perf_counter() - t0
        saved = float(np.mean([r["flops_saved"] for r in packed]))
        raw = float(np.mean([1 - r["density"] for r in packed]))
        rows.append((f"e2e,vgg_tiny,conv,blk{kb[0]}x{kb[1]}",
                     t_sparse * 1e6,
                     f"wall_dense_us={t_dense * 1e6:.0f};"
                     f"conv_packed_layers={len(packed)};"
                     f"mean_flops_saved_exec={saved:.2f};"
                     f"mean_raw_zero_frac={raw:.2f};"
                     f"pack_us={t_pack * 1e6:.0f}"))
    return rows


def bench(fast=True):
    rows = []
    arch = "yi-9b"
    cfg = configs.get(arch, smoke=True)
    batch, prompt, new = 4, 32, 16
    iters = 2 if fast else 5
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    b = synthetic_batch(0, 0, batch, prompt, cfg.vocab)
    toks = b["tokens"]

    # dense baseline: fused scan loop vs eager python loop
    t_fused = _timed(lambda: generate(params, cfg, toks, new), iters)
    t_eager = _timed(lambda: generate_python(params, cfg, toks, new), iters)
    tps = batch * new / t_fused
    rows.append((f"e2e,{arch},dense,fused", t_fused * 1e6,
                 f"tok_s={tps:.1f};eager_us={t_eager * 1e6:.0f};"
                 f"loop_speedup={t_eager / t_fused:.2f}x"))

    for zero_frac in ((0.5, 0.75) if fast else (0.25, 0.5, 0.75, 0.875)):
        masks = _block_masks(params, zero_frac)
        pm = apply_masks(params, masks)
        ops.clear_pack_cache()
        t0 = time.perf_counter()
        exec_params, report = compile_model(pm, masks, SPEC)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        compile_model(pm, masks, SPEC)           # content-cached repack
        t_warm = time.perf_counter() - t0
        packed = [r for r in report if r["packed"]]
        saved = (sum(r["flops_saved"] for r in packed) / len(packed)
                 if packed else 0.0)
        t_sparse = _timed(lambda: generate(exec_params, cfg, toks, new),
                          iters)
        rows.append((f"e2e,{arch},zf{zero_frac:.2f}", t_sparse * 1e6,
                     f"tok_s={batch * new / t_sparse:.1f};"
                     f"packed_layers={len(packed)};"
                     f"mean_flops_saved={saved:.2f};"
                     f"pack_cold_us={t_cold * 1e6:.0f};"
                     f"pack_cached_us={t_warm * 1e6:.0f}"))
    rows += _moe_rows(fast)
    rows += _conv_rows(fast)
    return rows
