"""Paper Table 5: MACs accounting for rule-mapped models (the MACs-matched
comparison row: 'Ours (Rule-based)')."""
from repro import configs
from repro.core import mapper_rule as MR


def _macs(layers, spec=None, compression=8.0):
    total = 0.0
    for l in layers:
        dense = l.M * l.K * l.N * l.count
        if spec is None:
            total += dense
            continue
        from repro.core.reweighted import match
        c = match(spec, l.path)
        if c is None or c.scheme == "none":
            total += dense
        elif c.scheme == "pattern":
            total += dense / 2.25
        else:
            total += dense / compression
    return total


def bench(fast=True):
    rows = []
    for arch in ("yi-9b", "mixtral-8x7b", "phi3-medium-14b",
                 "kimi-k2-1t-a32b"):
        cfg = configs.get(arch)
        layers = MR.lm_layers(cfg, tokens=1)     # per-token MACs
        dense = _macs(layers)
        for comp in (2.0, 4.0, 8.0):
            spec, _ = MR.map_rules(layers, dataset_hard=True,
                                   compression=comp)
            m = _macs(layers, spec, comp)
            rows.append((f"table5,{arch},comp{comp:.0f}x", 0.0,
                         f"macs={m:.3g};dense={dense:.3g};"
                         f"reduction={dense/m:.2f}x"))
    return rows
