"""Paper Fig 9/10: per-layer latency vs block size / feature size /
compression — the offline latency-model sweep (the artifact §5.2.1 builds;
512-setting table in <1s analytically vs ~30min measured on a phone)."""
import time

from repro.core.latency_model import (build_table, matmul_latency,
                                      conv_as_gemm)

FEATS = [(56, 64), (28, 128), (14, 256), (7, 512)]   # iso-MAC settings
BLOCKS = [(4, 4), (16, 32), (64, 128), (128, 128), (128, 256)]


def bench(fast=True):
    rows = []
    t0 = time.time()
    table = build_table()
    rows.append(("fig9_10,table_build", (time.time() - t0) * 1e6,
                 f"settings={len(table)}"))
    # Fig 9a: 1x1 conv latency vs block size across feature sizes
    for feat, ch in FEATS:
        M, K, N = conv_as_gemm(feat, ch, ch, 1, 1)
        for b in BLOCKS:
            if K % b[0] or N % b[1]:
                continue
            t = matmul_latency(M, K, N, scheme="block", block=b,
                               compression=8)
            rows.append((f"fig9,1x1conv,f{feat}c{ch},b{b[0]}x{b[1]}",
                         t * 1e6, "compression=8"))
    # Fig 10b: pattern vs block for a 3x3 CONV across compressions
    M, K, N = conv_as_gemm(28, 128, 128, 3, 3)
    for comp in (4, 8, 12, 16):
        tp = matmul_latency(M, K, N, scheme="pattern", compression=2.25)
        tb8 = matmul_latency(M, K, N, scheme="block", block=(8, 16),
                             compression=comp)
        tb16 = matmul_latency(M, K, N, scheme="block", block=(128, 128),
                              compression=comp)
        rows.append((f"fig10,3x3conv,comp{comp}x", tb16 * 1e6,
                     f"pattern_us={tp*1e6:.2f};block8x16_us={tb8*1e6:.2f};"
                     f"block128_us={tb16*1e6:.2f}"))
    return rows
