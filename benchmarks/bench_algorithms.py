"""Paper Table 1: GroupLasso vs ADMM vs Reweighted pruning algorithms.

Same budget each; report (loss, achieved compression, manual-rate?):
  - GroupLasso: fixed alpha=1 penalties (uniform shrink -> worse acc)
  - ADMM-proxy: projection to a MANUALLY set per-layer rate every k steps
  - Reweighted: dynamic alphas -> automatic rates (the paper's choice)
"""
import jax

from benchmarks.common import train_convnet, eval_convnet
from repro.core import reweighted as RW
from repro.core.reweighted import SchemeChoice
from repro.models import convnet as C

# c1 has in_ch=3 (indivisible by any block) — excluded, like the paper
# leaves first layers dense
SPEC = [(r"c[2-6]/w", SchemeChoice("block_punched", (4, 4)))]


def _flat(masks):
    """masks_for_spec returns the full param-structure tree; the convnet
    apply wants the flat {layer_name: w-mask} convention."""
    return {name: sub["w"] for name, sub in masks.items()
            if isinstance(sub, dict) and "w" in sub and sub["w"].ndim > 0}


def _mask_at(params, threshold_rate):
    tau = RW.global_threshold(params, SPEC, threshold_rate)
    return RW.masks_for_spec(params, SPEC, threshold=tau)


def bench(fast=True):
    steps = 160 if fast else 400
    rows = []
    # eps large enough that 1/(norm^2+eps) stays O(1/eps) for dead
    # groups — too-small eps makes reweighted gradients explode
    cfg = RW.ReweightedConfig(spec=tuple(SPEC), lam=1e-3, eps=1e-2)

    # -- reweighted (dynamic alphas)
    params = C.convnet_init(jax.random.PRNGKey(0), C.VGG_TINY)
    alphas = RW.init_alphas(params, SPEC)
    for phase in range(4):
        pen = lambda p: cfg.lam * RW.penalty(p, alphas, cfg)
        params = train_convnet(steps=steps // 4, params=params,
                               penalty_fn=pen)
        alphas = RW.update_alphas(params, cfg)
    masks = _mask_at(params, 0.6)
    params = train_convnet(steps=steps // 2, params=params,
                           masks=_flat(masks))
    rep = RW.sparsity_report(params, masks)["__overall__"]
    acc = eval_convnet(params, masks=_flat(masks))
    rows.append(("table1,reweighted", 0.0,
                 f"acc={acc:.3f};compression={rep['compression']:.2f};"
                 f"rate=auto"))

    # -- plain group lasso (alpha = 1 throughout)
    params = C.convnet_init(jax.random.PRNGKey(0), C.VGG_TINY)
    ones = RW.init_alphas(params, SPEC)
    pen = lambda p: cfg.lam * RW.penalty(p, ones, cfg)
    params = train_convnet(steps=steps, params=params, penalty_fn=pen)
    masks = _mask_at(params, 0.6)
    params = train_convnet(steps=steps // 2, params=params,
                           masks=_flat(masks))
    rep = RW.sparsity_report(params, masks)["__overall__"]
    acc = eval_convnet(params, masks=_flat(masks))
    rows.append(("table1,group_lasso", 0.0,
                 f"acc={acc:.3f};compression={rep['compression']:.2f};"
                 f"rate=auto"))

    # -- ADMM proxy: hard projection to a manual uniform rate
    params = C.convnet_init(jax.random.PRNGKey(0), C.VGG_TINY)
    for phase in range(4):
        params = train_convnet(steps=steps // 4, params=params)
        masks = RW.masks_for_spec(params, SPEC, default_rate=0.6)
        params = jax.tree_util.tree_map(
            lambda p, m: p if m.ndim == 0 else p * m, params, masks)
    params = train_convnet(steps=steps // 2, params=params,
                           masks=_flat(masks))
    rep = RW.sparsity_report(params, masks)["__overall__"]
    acc = eval_convnet(params, masks=_flat(masks))
    rows.append(("table1,admm_manual", 0.0,
                 f"acc={acc:.3f};compression={rep['compression']:.2f};"
                 f"rate=manual"))
    return rows
