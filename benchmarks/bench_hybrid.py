"""Paper Table 2 (YOLOv4 comparison, reproduced on the tiny conv net with a
5x5 and 1x1 layer): per-scheme compression / accuracy / modeled FPS, plus
the HYBRID mapping (pattern on 3x3 + block elsewhere) that wins."""

from benchmarks.common import train_convnet, eval_convnet
from repro.core import regularity as R
from repro.core.latency_model import matmul_latency, conv_as_gemm
from repro.models import convnet as C

ARCH = C.MOBILE_TINY   # has 3x3, depthwise, 1x1 and 5x5 layers


def _model_latency(masked_layers):
    """Modeled end-to-end latency: sum per-layer GEMM latencies."""
    t, feat = 0.0, 16
    cin = 3
    for (name, out, kh, kw, stride, dw) in ARCH:
        feat = feat // stride
        M, K, N = conv_as_gemm(feat, cin if not dw else 1, out, kh, kw)
        scheme, comp = masked_layers.get(name, ("none", 1.0))
        # MXU-sane block for the latency estimate (tiny conv layers
        # can't fill 128x128 tiles; util scales with the block)
        t += matmul_latency(M, K, N, scheme=scheme,
                            block=(min(128, K), min(128, N)),
                            compression=comp)
        if not dw:
            cin = out
    return t


def _apply(dense, plan, steps):
    masks = {}
    comp_num, comp_den = 0.0, 0.0
    for (name, out, kh, kw, stride, dw) in ARCH:
        w = dense[name]["w"]
        comp_den += w.size
        scheme = plan.get(name)
        if scheme is None:
            comp_num += w.size
            continue
        if scheme == "pattern":
            masks[name] = R.pattern_mask(w, connectivity_rate=0.5)
        elif scheme == "unstructured":
            masks[name] = R.unstructured_mask(w, rate=0.8)
        elif scheme == "structured":
            masks[name] = R.structured_mask(w, rate=0.8, axis="row")
        elif scheme == "block":
            bp = (min(8, w.shape[0]), min(8, w.shape[1]))
            if w.ndim == 4:
                masks[name] = R.block_punched_mask(w, bp, rate=0.8)
            else:
                masks[name] = R.block_mask(w, bp, rate=0.8)
        comp_num += float(masks[name].sum()) if name in masks else w.size
    p = train_convnet(arch=ARCH, steps=steps, params=dense, masks=masks)
    acc = eval_convnet(p, arch=ARCH, masks=masks)
    return acc, comp_den / max(comp_num, 1.0)


def bench(fast=True):
    steps = 100 if fast else 250
    rows = []
    dense = train_convnet(arch=ARCH, steps=2 * steps, seed=3)
    acc_d = eval_convnet(dense, arch=ARCH)
    lat = _model_latency({})
    rows.append(("table2,not_prune", lat * 1e6,
                 f"acc={acc_d:.3f};compression=1.0"))

    threes = [n for (n, o, kh, kw, s, dw) in ARCH if kh == 3 and not dw]
    others = [n for (n, o, kh, kw, s, dw) in ARCH
              if (kh != 3 and not dw)]
    plans = {
        "structured": ({n: "structured" for n in threes + others},
                       {n: ("structured_row", 5.0) for n in threes + others}),
        "unstructured": ({n: "unstructured" for n in threes + others},
                         {n: ("unstructured", 5.0) for n in threes + others}),
        "pattern_3x3_only": ({n: "pattern" for n in threes},
                             {n: ("pattern", 2.25) for n in threes}),
        "block_all": ({n: "block" for n in threes + others},
                      {n: ("block", 5.0) for n in threes + others}),
        "hybrid": ({**{n: "pattern" for n in threes},
                    **{n: "block" for n in others}},
                   {**{n: ("pattern", 2.25) for n in threes},
                    **{n: ("block", 5.0) for n in others}}),
    }
    for label, (plan, latplan) in plans.items():
        acc, comp = _apply(dense, plan, steps)
        lat = _model_latency(latplan)
        rows.append((f"table2,{label}", lat * 1e6,
                     f"acc={acc:.3f};compression={comp:.2f};"
                     f"fps={1.0/lat:.0f}"))
    return rows
