"""Kernel-level benchmark: BCS Pallas kernel FLOP skipping + metadata
compression vs plain CSR across block densities (the §4.3 compiler
contribution, quantified), plus packing throughput — vectorized
argsort/cumsum CSC construction vs the pure-Python loop packer at
K=N=2048.  Wall-time on TPU is not measurable in this container; we report
modeled time + exact *effective* skipped-FLOP fractions (uniform-padded
layout, L/Kb) and run the interpret-mode kernel for correctness
side-effect."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcs as BCS
from repro.core.latency_model import matmul_latency
from repro.kernels import ops
from repro.kernels.ref import masked_matmul_ref


def _block_mask(K, N, blk, zero_frac, seed=2):
    keep = jax.random.uniform(jax.random.PRNGKey(seed),
                              (K // blk[0], N // blk[1])) >= zero_frac
    return jnp.repeat(jnp.repeat(keep, blk[0], 0), blk[1], 1)


def _best_of(fn, n=3, warmup=True):
    """min-of-n wall time; blocks on returned device arrays so async XLA
    dispatch doesn't flatter the measurement.  ``warmup=False`` for pure-
    Python paths with no jit compile to amortize."""
    if warmup:
        fn()
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return min(times)


def bench_packing(fast=True):
    """Vectorized vs loop packer, K=N=2048 (acceptance: >=10x at (4,4)).

    zero_frac=0 is the packing-throughput worst case — every block survives,
    so the per-block Python overhead of the loop packer is fully exposed and
    the comparison is least sensitive to mask randomness."""
    rows = []
    K = N = 2048
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (K, N)))
    for blk in ((4, 4), (8, 16), (64, 64)):
        mask = np.asarray(_block_mask(K, N, blk, 0.0), np.float32)

        def vec():
            return BCS.pack_csc(w, mask, blk)[0]   # serve-path (ops.pack)

        def loop():
            return BCS.pad_to_uniform_csc_loop(
                BCS.from_dense_loop(w, mask, blk))[0]

        tv = _best_of(vec, 3 if fast else 5)
        tl = _best_of(loop, 1 if fast else 2, warmup=False)
        rows.append((f"pack_vectorized,block{blk[0]}x{blk[1]}", tv * 1e6,
                     f"loop_us={tl * 1e6:.0f};speedup={tl / tv:.1f}x"))
    return rows


def _skewed_mask(K, N, blk, heavy_cols=1, light_degree=1, seed=3):
    """Degree-skewed fixture: ``heavy_cols`` block columns keep every
    K-block, the rest keep ``light_degree`` random blocks — the worst case
    for uniform padding (one heavy column sets L for everyone) and the
    best case for row reordering/binning."""
    Kb, Nb = K // blk[0], N // blk[1]
    keep = np.zeros((Kb, Nb), bool)
    keep[:, :heavy_cols] = True
    rng = np.random.default_rng(seed)
    for j in range(heavy_cols, Nb):
        keep[rng.choice(Kb, light_degree, replace=False), j] = True
    return jnp.asarray(np.repeat(np.repeat(keep, blk[0], 0), blk[1], 1),
                       jnp.float32)


def bench_reorder(fast=True):
    """Row reordering on the skewed-degree fixture: padded L must drop
    strictly (toward the mean degree) with bit-identical outputs."""
    rows = []
    K, N, M, blk = 512, 512, 128, (64, 64)
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    mask = _skewed_mask(K, N, blk)
    plain = ops.pack(w, mask, blk)
    for n_bins in ((2, 4) if fast else (2, 4, 8)):
        reord = ops.pack(w, mask, blk, reorder=True, n_bins=n_bins)
        y0 = ops.sparse_linear(x, packed=plain, bm=64)
        y1 = ops.sparse_linear(x, packed=reord, bm=64)
        bit_identical = bool(np.array_equal(np.asarray(y0), np.asarray(y1)))
        t = matmul_latency(M, K, N, scheme="block", block=blk,
                           compression=(plain.Kb * plain.Nb)
                           / max(reord.executed_blocks, 1))
        rows.append((f"reorder,bins{n_bins}", t * 1e6,
                     f"L_max={plain.L_max};L_reordered={reord.L_effective:.2f};"
                     f"L_reduced={reord.L_effective < plain.L_max};"
                     f"flops_skipped_eff={ops.flops_saved(reord):.2f};"
                     f"unreordered_skipped={ops.flops_saved(plain):.2f};"
                     f"bit_identical={bit_identical}"))
    return rows


def bench(fast=True):
    rows = []
    K, N, M, blk = 512, 512, 128, (64, 64)
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    for zero_frac in (0.0, 0.25, 0.5, 0.75, 0.875):
        mask = _block_mask(K, N, blk, zero_frac)
        packed = ops.pack(w, mask.astype(jnp.float32), blk)
        y = ops.sparse_linear(x, packed=packed, bm=64)
        y_ref = masked_matmul_ref(x, w, mask.astype(jnp.float32))
        err = float(jnp.max(jnp.abs(y - y_ref)))
        b = BCS.from_dense(np.asarray(w), np.asarray(mask, np.float32), blk)
        t = matmul_latency(M, K, N, scheme="block", block=blk,
                           compression=1.0 / max(packed.density, 1e-6))
        rows.append((f"kernel,density{packed.density:.2f}", t * 1e6,
                     f"flops_skipped_eff={ops.flops_saved(packed):.2f};"
                     f"pad_overhead={ops.padding_overhead(packed):.2f};"
                     f"idx_bytes={b.index_bytes()};"
                     f"csr_bytes={b.csr_index_bytes()};max_err={err:.1e}"))
    rows += bench_reorder(fast)
    rows += bench_packing(fast)
    return rows
