"""Kernel-level benchmark: BCS Pallas kernel FLOP skipping + metadata
compression vs plain CSR, across block densities (the §4.3 compiler
contribution, quantified).  Wall-time on TPU is not measurable in this
container; we report modeled time + exact skipped-FLOP fractions and run
the interpret-mode kernel for correctness side-effect."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcs as BCS
from repro.core.latency_model import matmul_latency
from repro.kernels import ops
from repro.kernels.ref import masked_matmul_ref


def bench(fast=True):
    rows = []
    K, N, M, blk = 512, 512, 128, (64, 64)
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    for zero_frac in (0.0, 0.25, 0.5, 0.75, 0.875):
        keep = jax.random.uniform(jax.random.PRNGKey(2),
                                  (K // blk[0], N // blk[1])) >= zero_frac
        mask = jnp.repeat(jnp.repeat(keep, blk[0], 0), blk[1], 1)
        packed = ops.pack(w, mask.astype(jnp.float32), blk)
        y = ops.sparse_linear(x, packed=packed, bm=64)
        y_ref = masked_matmul_ref(x, w, mask.astype(jnp.float32))
        err = float(jnp.max(jnp.abs(y - y_ref)))
        b = BCS.from_dense(np.asarray(w), np.asarray(mask, np.float32), blk)
        t = matmul_latency(M, K, N, scheme="block", block=blk,
                           compression=1.0 / max(packed["density"], 1e-6))
        rows.append((f"kernel,density{packed['density']:.2f}", t * 1e6,
                     f"flops_skipped={ops.flops_saved(packed):.2f};"
                     f"idx_bytes={b.index_bytes()};"
                     f"csr_bytes={b.csr_index_bytes()};max_err={err:.1e}"))
    return rows
