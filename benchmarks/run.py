"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses longer training
budgets; default is the fast CI-sized pass.  ``--json`` additionally writes
one ``BENCH_<name>.json`` per module (rows + timestamp) so successive PRs
accumulate a machine-readable perf trajectory."""
import argparse
import importlib
import json
import sys
import time

BENCHES = [
    "bench_latency_model",    # Fig 9/10 (latency model sweeps)
    "bench_kernel",           # §4.3 BCS kernel skipping + packing speed
    "bench_e2e_sparse",       # whole-model prefill+decode via compile_model
    "bench_serving",          # continuous-batching engine: tok/s + occupancy
    "bench_faults",           # chaos harness: degraded tok/s + recovery bound
    "bench_coldstart",        # AOT artifact store: cold pack vs warm load
    "bench_moe_sparse",       # batched sparse MoE expert GEMMs vs dense
    "bench_conv_sparse",      # conv via im2col PackedLayout (Fig 5 sweep)
    "bench_quant",            # int8 packed values vs fp: bytes + parity
    "bench_shard",            # tensor-parallel shard balance + tp scaling
    "bench_macs",             # Table 5
    "bench_portability",      # Table 7
    "bench_blocksize",        # Fig 5 + Fig 9 (acc/latency vs block)
    "bench_pattern_vs_block", # Fig 7 / Remark 1
    "bench_algorithms",       # Table 1
    "bench_hybrid",           # Table 2
    "bench_mapping",          # Table 4
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json per module")
    args = ap.parse_args()
    names = [b for b in BENCHES if args.only is None or args.only in b]
    if not names:
        raise SystemExit(
            f"--only {args.only!r} matches no benchmark suite; "
            f"choose a substring of one of: {', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.bench(fast=not args.full)
        except Exception as e:  # noqa: BLE001
            failures.append((name, str(e)))
            print(f"{name},ERROR,{str(e)[:120]!r}", flush=True)
            continue
        for (n, us, derived) in rows:
            print(f"{n},{us:.2f},{derived}", flush=True)
        elapsed = time.time() - t0
        print(f"# {name} done in {elapsed:.1f}s", file=sys.stderr)
        if args.json:
            short = name.removeprefix("bench_")
            payload = {
                "bench": name,
                "elapsed_s": round(elapsed, 2),
                "unix_time": int(time.time()),
                "rows": [{"name": n, "us_per_call": round(us, 2),
                          "derived": derived} for (n, us, derived) in rows],
            }
            path = f"BENCH_{short}.json"
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {path}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
