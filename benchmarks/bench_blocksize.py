"""Paper Fig 5 + Fig 9: accuracy & latency vs block size.

Accuracy: small convnet, block-punched pruning at 8x, short finetune.
Latency: the offline TPU latency model for the same layer shapes.
Reproduces the paper's qualitative result: unstructured (1x1) = best acc /
worst latency; whole-matrix = worst acc / best latency; mid blocks win."""

from benchmarks.common import train_convnet, eval_convnet
from repro.core import regularity as R
from repro.core.latency_model import matmul_latency, conv_as_gemm
from repro.models import convnet as C

BLOCKS = [(1, 1), (4, 4), (8, 8), (16, 16), (32, 32)]


def bench(fast=True):
    rows = []
    steps = 150 if fast else 400
    dense = train_convnet(steps=steps)
    acc_dense = eval_convnet(dense)
    rows.append(("fig5_blocksize,dense", 0.0, f"acc={acc_dense:.3f}"))
    for b in BLOCKS:
        masks = {}
        for (name, out, kh, kw, stride, dw) in C.VGG_TINY:
            w = dense[name]["w"]
            if dw or kh != 3 or w.shape[0] < b[0] or w.shape[1] < b[1]:
                continue
            masks[name] = R.block_punched_mask(w, b, rate=0.75)
        p = train_convnet(steps=steps // 2, params=dense, masks=masks)
        acc = eval_convnet(p, masks=masks)
        M, K, N = conv_as_gemm(14, 128, 128, 3, 3)
        lat = matmul_latency(M, K, N, scheme="block",
                             block=(max(b[0], 1), max(b[1], 1)),
                             compression=8.0)
        rows.append((f"fig5_blocksize,{b[0]}x{b[1]}", lat * 1e6,
                     f"acc={acc:.3f}"))
    return rows
