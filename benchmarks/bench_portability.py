"""Paper Table 7: portability — the same rule-based mapping re-derived for
three TPU generations (v4 / v5e / v5p instead of S10/S20/S21).  The mapping
method is hardware-agnostic; only the latency-model constants change."""
from repro import configs
from repro.core import mapper_rule as MR
from repro.core.latency_model import V4, V5E, V5P


def bench(fast=True):
    rows = []
    cfg = configs.get("yi-9b")
    layers = MR.lm_layers(cfg, tokens=32768)
    for target in (V4, V5E, V5P):
        spec, rep = MR.map_rules(layers, dataset_hard=True,
                                 compression=8.0, target=target)
        blocks = {r["block"] for r in rep if r["scheme"] == "block"}
        rows.append((f"table7,{target.name}",
                     MR.total_latency(rep) * 1e6,
                     f"blocks={sorted(blocks)};layers={len(rep)}"))
    return rows
