"""CONV layers through the BCS sparse path — the Fig 5 block-size sweep at
the layer level, reported in *executed-L* terms.

For a serving-ish conv layer the kernel-block sweep packs a block-punched
mask through the im2col lowering (``core.bcs.conv_lower``) and reports the
modeled GEMM latency at the layout's executed-block count (wall-clock on
TPU is not measurable in this container; same convention as bench_kernel),
the effective skipped-FLOP fraction (1 - executed/(Kb*Nb)) next to the raw
zero fraction it replaces, the row-reordering speedup (unreordered vs
binned executed-L — the deterministic load-balance win; small punched
blocks are MXU-hostile by design, so speedup-vs-dense is the *mapper's*
trade-off, covered by bench_mapping), and the parity error of
``kernels.ops.sparse_conv2d`` against the masked ``lax.conv`` oracle.  A
5x5 stride-2 row covers the non-3x3 case the paper calls out; whole-model
conv rows (VGG_TINY through ``compile_model``) live in the conv section of
``bench_e2e_sparse``.  Emitted rows land in BENCH_conv_sparse.json under
``run.py --json``."""
import jax
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core.latency_model import conv_as_gemm, matmul_latency
from repro.kernels import ops


def _layer_row(P, Q, kh, kw, stride, kernel_block, feat=14, rate=0.6,
               seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, kernel_block, rate=rate)
    wm = w * mask
    gemm_block, why = BCS.conv_gemm_block(kernel_block, w.shape)
    assert gemm_block is not None, why
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    plain = ops.pack(wl, ml, gemm_block)
    reord = ops.pack(wl, ml, gemm_block, reorder=True, n_bins=4)
    # output positions under SAME padding: ceil(feat/stride) per dim
    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw)

    def modeled_us(layout):
        comp = (layout.Kb * layout.Nb) / max(layout.executed_blocks, 1)
        return matmul_latency(M, K, N, scheme="block_punched",
                              block=gemm_block, compression=comp) * 1e6

    us_sparse = modeled_us(reord)
    us_plain = modeled_us(plain)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d(x, reord, kh=kh, kw=kw, stride=stride)
    kernel = wm.transpose(2, 3, 1, 0)
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    bp, bq = kernel_block
    return (f"conv,{P}x{Q}x{kh}x{kw},s{stride},blk{bp}x{bq}", us_sparse,
            f"unreordered_us={us_plain:.1f};"
            f"reorder_speedup={us_plain / us_sparse:.2f}x;"
            f"flops_saved_exec={reord.flops_saved:.2f};"
            f"raw_zero_frac={1 - reord.density:.2f};"
            f"L={plain.L_max}->{reord.L_effective:.2f};max_err={err:.1e}")


def bench(fast=True):
    rows = []
    # Fig 5 analogue: kernel-block sweep on a serving-ish 3x3 conv
    for kb in (((4, 4), (8, 8)) if fast else ((4, 4), (8, 8), (16, 16))):
        rows.append(_layer_row(128, 64, 3, 3, 1, kb))
    # the paper's non-3x3 point: 5x5 kernel, stride 2
    rows.append(_layer_row(128, 64, 5, 5, 2, (8, 8)))
    return rows
