"""CONV layers through the sparse paths — the Fig 5 block-size sweep at
the layer level, reported in *executed-L* terms, plus the pattern/
connectivity rows through the tap-gather kernel.

For a serving-ish conv layer the kernel-block sweep packs a block-punched
mask through the im2col lowering (``core.bcs.conv_lower``) and reports the
modeled GEMM latency at the layout's executed-block count (wall-clock on
TPU is not measurable in this container; same convention as bench_kernel),
the effective skipped-FLOP fraction (1 - executed/(Kb*Nb)) next to the raw
zero fraction it replaces, the row-reordering speedup (unreordered vs
binned executed-L — the deterministic load-balance win; small punched
blocks are MXU-hostile by design, so speedup-vs-dense is the *mapper's*
trade-off, covered by bench_mapping), and the parity error of
``kernels.ops.sparse_conv2d`` against the masked ``lax.conv`` oracle.  A
5x5 stride-2 row covers the non-3x3 case the paper calls out; whole-model
conv rows (VGG_TINY through ``compile_model``) live in the conv section of
``bench_e2e_sparse``.

Pattern rows (``pattern,...``) cover the tap-gather path: a 4-of-9
pattern mask (optionally with connectivity pruning, and a connectivity-
only 5x5 row) is tap-lowered (``core.bcs.pattern_lower``) and the row
reports the *executed-tap* savings of the padded ``TapLayout`` (what the
kernel multiplies, NOT raw mask density), the degree-binning gain on the
tap lists, the modeled tap-gather latency next to the modeled dense conv
(pattern is the accuracy-first scheme — on TPU the tap gather runs at VPU
efficiency, so the win is skipped work and HBM, not MXU throughput), and
the kernel's parity error against the masked ``lax.conv`` oracle.

Every conv row also reports the HBM megabytes its GEMM moves on both
x-operand strategies (``hbm_mat_mb``: patch read + weights + output;
``hbm_imp_mb``: padded feature-map read + weights + output) so the
implicit-GEMM speedup is explainable from traffic, not just observed.
``implicit,...`` rows compare materialized vs implicit end to end at
VGG/MOBILE-scale shapes: modeled latency (``implicit_speedup``, gated —
never < 1 since the paths differ only in activation traffic), peak
working set (``peak_imp_mb``/``peak_mat_mb``, deterministic byte
accounting, gated lower-is-better — the patch tensor is the gap), and
interpret-mode wall time (info only).  The ``tap_bins`` row locks the
n_bins=8 default for connectivity-bearing tap layouts
(``bin8_speedup`` = 4-bin padding overhead / 8-bin padding overhead).
Emitted rows land in BENCH_conv_sparse.json under ``run.py --json``."""
import time

import jax
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core.latency_model import conv_as_gemm, im2col_x_frac, \
    matmul_latency
from repro.kernels import ops
from repro.kernels.bsr_matmul import conv_geometry

_F4 = 4  # fp32 bytes — every conv bench runs fp32


def _layout_mb(layout):
    return ops._entry_bytes(layout) / 1e6


def _traffic_mb(B, H, W, Q, P, kh, kw, stride, w_mb):
    """(patch, padded-input, output, weights+output) megabytes for one
    conv-as-GEMM: the materialized path reads the patch tensor, the
    implicit path the padded feature map; weights + output are common."""
    ph, pw, Ho, Wo = conv_geometry(H, W, kh, kw, stride)
    M = B * Ho * Wo
    patch_mb = M * kh * kw * Q * _F4 / 1e6
    padded_mb = B * (H + ph[0] + ph[1]) * (W + pw[0] + pw[1]) * Q * _F4 / 1e6
    out_mb = M * P * _F4 / 1e6
    return patch_mb, padded_mb, out_mb, w_mb + out_mb


def _layer_row(P, Q, kh, kw, stride, kernel_block, feat=14, rate=0.6,
               seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, kernel_block, rate=rate)
    wm = w * mask
    gemm_block, why = BCS.conv_gemm_block(kernel_block, w.shape)
    assert gemm_block is not None, why
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    plain = ops.pack(wl, ml, gemm_block)
    reord = ops.pack(wl, ml, gemm_block, reorder=True, n_bins=4)
    # output positions under SAME padding: ceil(feat/stride) per dim
    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw)

    def modeled_us(layout):
        comp = (layout.Kb * layout.Nb) / max(layout.executed_blocks, 1)
        return matmul_latency(M, K, N, scheme="block_punched",
                              block=gemm_block, compression=comp) * 1e6

    us_sparse = modeled_us(reord)
    us_plain = modeled_us(plain)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d(x, reord, kh=kh, kw=kw, stride=stride)
    kernel = wm.transpose(2, 3, 1, 0)
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    patch_mb, padded_mb, out_mb, common_mb = _traffic_mb(
        1, feat, feat, Q, P, kh, kw, stride, _layout_mb(reord))
    bp, bq = kernel_block
    return (f"conv,{P}x{Q}x{kh}x{kw},s{stride},blk{bp}x{bq}", us_sparse,
            f"unreordered_us={us_plain:.1f};"
            f"reorder_speedup={us_plain / us_sparse:.2f}x;"
            f"flops_saved_exec={reord.flops_saved:.2f};"
            f"raw_zero_frac={1 - reord.density:.2f};"
            f"L={plain.L_max}->{reord.L_effective:.2f};"
            f"hbm_mat_mb={patch_mb + common_mb:.3f};"
            f"hbm_imp_mb={padded_mb + common_mb:.3f};max_err={err:.1e}")


def _pattern_case(P, Q, kh, kw, connectivity, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    if (kh, kw) == (3, 3):
        mask = R.pattern_mask(w, connectivity_rate=connectivity)
    else:                      # non-3x3: the scheme's connectivity half
        mask = R.connectivity_mask(w, rate=connectivity)
    return w * mask, mask


def _pattern_row(P, Q, kh, kw, stride, connectivity, feat=14, seed=0):
    wm, mask = _pattern_case(P, Q, kh, kw, connectivity, seed)
    plain = ops.pack_taps(wm, mask, reorder=False)
    tap = ops.pack_taps(wm, mask, reorder=True)    # default bins (8)
    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw)

    def modeled_us(layout):
        frac = 1.0 - layout.flops_saved
        return matmul_latency(M, K, N, scheme="pattern",
                              compression=1 / max(frac, 1e-9),
                              executed_frac=frac) * 1e6

    us_tap = modeled_us(tap)
    us_plain = modeled_us(plain)
    us_dense = matmul_latency(M, K, N) * 1e6
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d_pattern(x, tap, kh=kh, kw=kw, stride=stride)
    kernel = wm.transpose(2, 3, 1, 0)
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    patch_mb, padded_mb, out_mb, common_mb = _traffic_mb(
        1, feat, feat, Q, P, kh, kw, stride, _layout_mb(tap))
    # the materialized tap path reads the alive band, not the full patch
    band_mb = patch_mb * tap.n_alive / tap.shape[0]
    return (f"pattern,{P}x{Q}x{kh}x{kw},s{stride},conn{connectivity:.1f}",
            us_tap,
            f"unreordered_us={us_plain:.1f};"
            f"reorder_speedup={us_plain / us_tap:.2f}x;"
            f"flops_saved_exec={tap.flops_saved:.2f};"
            f"raw_zero_frac={1 - tap.density:.2f};"
            f"L={plain.L_max}->{tap.L_effective:.2f};"
            f"alive_band={tap.n_alive}/{tap.shape[0]};"
            f"hbm_mat_mb={band_mb + common_mb:.3f};"
            f"hbm_imp_mb={padded_mb + common_mb:.3f};"
            f"dense_us={us_dense:.1f};max_err={err:.1e}")


def _wall_us(fn, iters=2):
    jax.block_until_ready(fn())                   # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6


def _implicit_row(tag, P, Q, kh, kw, stride, feat, batch, *, pattern,
                  wall_feat, seed=0):
    """Materialized vs implicit at a serving-scale shape: modeled latency
    at the layout's executed cost with each path's activation traffic
    (``im2col_x_frac``), deterministic peak-working-set accounting (the
    patch tensor is the whole gap), and interpret-mode wall time measured
    at ``wall_feat`` (info only — interpret wall is not TPU wall)."""
    if pattern:
        wm, mask = _pattern_case(P, Q, kh, kw, 0.5, seed)
        layout = ops.pack_taps(wm, mask)
        frac = 1.0 - layout.flops_saved
        conv = lambda x, imp: ops.sparse_conv2d_pattern(   # noqa: E731
            x, layout, kh=kh, kw=kw, stride=stride, implicit=imp)

        def modeled(M, K, N, implicit):
            return matmul_latency(
                M, K, N, scheme="pattern", compression=1 / max(frac, 1e-9),
                executed_frac=frac,
                x_frac=im2col_x_frac(kh * kw, implicit)) * 1e6
    else:
        w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                              jnp.float32) * 0.1
        kernel_block = (64, 64)
        mask = R.block_punched_mask(w, kernel_block, rate=0.6)
        wm = w * mask
        gemm_block, why = BCS.conv_gemm_block(kernel_block, w.shape)
        assert gemm_block is not None, why
        layout = ops.pack(BCS.conv_lower(wm), BCS.conv_lower(mask),
                          gemm_block, reorder=True, n_bins=4,
                          conv=(kh, kw, Q))
        conv = lambda x, imp: ops.sparse_conv2d(           # noqa: E731
            x, layout, kh=kh, kw=kw, stride=stride, implicit=imp)

        def modeled(M, K, N, implicit):
            comp = (layout.Kb * layout.Nb) / max(layout.executed_blocks, 1)
            return matmul_latency(
                M, K, N, scheme="block_punched", block=gemm_block,
                compression=comp,
                x_frac=im2col_x_frac(kh * kw, implicit)) * 1e6

    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw, batch=batch)
    us_mat, us_imp = modeled(M, K, N, False), modeled(M, K, N, True)
    w_mb = _layout_mb(layout)
    patch_mb, padded_mb, out_mb, _ = _traffic_mb(
        batch, feat, feat, Q, P, kh, kw, stride, w_mb)
    x_mb = batch * feat * feat * Q * _F4 / 1e6
    peak_mat = x_mb + patch_mb + w_mb + out_mb
    peak_imp = x_mb + padded_mb + w_mb + out_mb
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, wall_feat, wall_feat, Q), jnp.float32)
    wall_mat = _wall_us(lambda: conv(x, False))
    wall_imp = _wall_us(lambda: conv(x, True))
    err = float(jnp.max(jnp.abs(conv(x, True) - conv(x, False))))
    return (f"implicit,{tag},{P}x{Q}x{kh}x{kw},s{stride},f{feat}b{batch}",
            us_imp,
            f"materialized_us={us_mat:.1f};"
            f"implicit_speedup={us_mat / us_imp:.2f}x;"
            f"peak_imp_mb={peak_imp:.2f};peak_mat_mb={peak_mat:.2f};"
            f"patch_avoided={patch_mb - padded_mb + x_mb:.2f}MB;"
            f"wall_us_mat={wall_mat:.0f};wall_us_imp={wall_imp:.0f};"
            f"max_err={err:.1e}")


def _bin_row(P=128, Q=64, seed=0):
    """Lock the raised tap-bin default: on a connectivity-bearing layout,
    8 bins must keep strictly less padding than 4 (ROADMAP: ~89% vs ~75%
    of the 1-bin -> ideal gap recovered)."""
    wm, mask = _pattern_case(P, Q, 3, 3, 0.5, seed)
    b1 = ops.pack_taps(wm, mask, n_bins=1)
    b4 = ops.pack_taps(wm, mask, n_bins=4)
    b8 = ops.pack_taps(wm, mask)                  # default = 8
    gap = b1.padding_overhead - 1.0
    rec4 = (b1.padding_overhead - b4.padding_overhead) / gap
    rec8 = (b1.padding_overhead - b8.padding_overhead) / gap
    return (f"tap_bins,{P}x{Q}x3x3,conn0.5", 0.0,
            f"bin8_speedup={b4.padding_overhead / b8.padding_overhead:.3f}x;"
            f"overhead_1bin={b1.padding_overhead:.3f};"
            f"overhead_4bin={b4.padding_overhead:.3f};"
            f"overhead_8bin={b8.padding_overhead:.3f};"
            f"gap_recovered_4bin={rec4:.2f};gap_recovered_8bin={rec8:.2f}")


def bench(fast=True):
    rows = []
    # Fig 5 analogue: kernel-block sweep on a serving-ish 3x3 conv
    for kb in (((4, 4), (8, 8)) if fast else ((4, 4), (8, 8), (16, 16))):
        rows.append(_layer_row(128, 64, 3, 3, 1, kb))
    # the paper's non-3x3 point: 5x5 kernel, stride 2
    rows.append(_layer_row(128, 64, 5, 5, 2, (8, 8)))
    # tap-gather rows: pure 4-of-9 patterns, patterns + connectivity, and
    # the connectivity-only 5x5 — executed-tap savings, not raw density
    rows.append(_pattern_row(128, 64, 3, 3, 1, 0.0))
    rows.append(_pattern_row(128, 64, 3, 3, 1, 0.5))
    rows.append(_pattern_row(128, 64, 5, 5, 2, 0.5))
    # implicit-GEMM vs materialized at serving-scale shapes: the VGG-scale
    # 3x3 block-punched layer and the MOBILE-style 5x5 pattern layer
    rows.append(_implicit_row("VGG", 128, 64, 3, 3, 1, 56, 2,
                              pattern=False, wall_feat=28 if fast else 56))
    rows.append(_implicit_row("MOBILE", 128, 128, 5, 5, 1, 28, 2,
                              pattern=True, wall_feat=14 if fast else 28))
    # the raised tap-bin default, locked against the padding gap
    rows.append(_bin_row())
    return rows
