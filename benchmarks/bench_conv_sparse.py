"""CONV layers through the sparse paths — the Fig 5 block-size sweep at
the layer level, reported in *executed-L* terms, plus the pattern/
connectivity rows through the tap-gather kernel.

For a serving-ish conv layer the kernel-block sweep packs a block-punched
mask through the im2col lowering (``core.bcs.conv_lower``) and reports the
modeled GEMM latency at the layout's executed-block count (wall-clock on
TPU is not measurable in this container; same convention as bench_kernel),
the effective skipped-FLOP fraction (1 - executed/(Kb*Nb)) next to the raw
zero fraction it replaces, the row-reordering speedup (unreordered vs
binned executed-L — the deterministic load-balance win; small punched
blocks are MXU-hostile by design, so speedup-vs-dense is the *mapper's*
trade-off, covered by bench_mapping), and the parity error of
``kernels.ops.sparse_conv2d`` against the masked ``lax.conv`` oracle.  A
5x5 stride-2 row covers the non-3x3 case the paper calls out; whole-model
conv rows (VGG_TINY through ``compile_model``) live in the conv section of
``bench_e2e_sparse``.

Pattern rows (``pattern,...``) cover the tap-gather path: a 4-of-9
pattern mask (optionally with connectivity pruning, and a connectivity-
only 5x5 row) is tap-lowered (``core.bcs.pattern_lower``) and the row
reports the *executed-tap* savings of the padded ``TapLayout`` (what the
kernel multiplies, NOT raw mask density), the degree-binning gain on the
tap lists, the modeled tap-gather latency next to the modeled dense conv
(pattern is the accuracy-first scheme — on TPU the tap gather runs at VPU
efficiency, so the win is skipped work and HBM, not MXU throughput), and
the kernel's parity error against the masked ``lax.conv`` oracle.
Emitted rows land in BENCH_conv_sparse.json under ``run.py --json``."""
import jax
import jax.numpy as jnp

from repro.core import bcs as BCS
from repro.core import regularity as R
from repro.core.latency_model import conv_as_gemm, matmul_latency
from repro.kernels import ops


def _layer_row(P, Q, kh, kw, stride, kernel_block, feat=14, rate=0.6,
               seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    mask = R.block_punched_mask(w, kernel_block, rate=rate)
    wm = w * mask
    gemm_block, why = BCS.conv_gemm_block(kernel_block, w.shape)
    assert gemm_block is not None, why
    wl, ml = BCS.conv_lower(wm), BCS.conv_lower(mask)
    plain = ops.pack(wl, ml, gemm_block)
    reord = ops.pack(wl, ml, gemm_block, reorder=True, n_bins=4)
    # output positions under SAME padding: ceil(feat/stride) per dim
    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw)

    def modeled_us(layout):
        comp = (layout.Kb * layout.Nb) / max(layout.executed_blocks, 1)
        return matmul_latency(M, K, N, scheme="block_punched",
                              block=gemm_block, compression=comp) * 1e6

    us_sparse = modeled_us(reord)
    us_plain = modeled_us(plain)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d(x, reord, kh=kh, kw=kw, stride=stride)
    kernel = wm.transpose(2, 3, 1, 0)
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    bp, bq = kernel_block
    return (f"conv,{P}x{Q}x{kh}x{kw},s{stride},blk{bp}x{bq}", us_sparse,
            f"unreordered_us={us_plain:.1f};"
            f"reorder_speedup={us_plain / us_sparse:.2f}x;"
            f"flops_saved_exec={reord.flops_saved:.2f};"
            f"raw_zero_frac={1 - reord.density:.2f};"
            f"L={plain.L_max}->{reord.L_effective:.2f};max_err={err:.1e}")


def _pattern_row(P, Q, kh, kw, stride, connectivity, feat=14, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (P, Q, kh, kw),
                          jnp.float32) * 0.1
    if (kh, kw) == (3, 3):
        mask = R.pattern_mask(w, connectivity_rate=connectivity)
    else:                      # non-3x3: the scheme's connectivity half
        mask = R.connectivity_mask(w, rate=connectivity)
    wm = w * mask
    plain = ops.pack_taps(wm, mask, reorder=False)
    tap = ops.pack_taps(wm, mask, reorder=True, n_bins=4)
    M, K, N = conv_as_gemm(-(-feat // stride), Q, P, kh, kw)

    def modeled_us(layout):
        frac = 1.0 - layout.flops_saved
        return matmul_latency(M, K, N, scheme="pattern",
                              compression=1 / max(frac, 1e-9),
                              executed_frac=frac) * 1e6

    us_tap = modeled_us(tap)
    us_plain = modeled_us(plain)
    us_dense = matmul_latency(M, K, N) * 1e6
    x = jax.random.normal(jax.random.PRNGKey(1), (1, feat, feat, Q),
                          jnp.float32)
    y = ops.sparse_conv2d_pattern(x, tap, kh=kh, kw=kw, stride=stride)
    kernel = wm.transpose(2, 3, 1, 0)
    y_ref = jax.lax.conv_general_dilated(
        x, kernel, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    err = float(jnp.max(jnp.abs(y - y_ref)))
    return (f"pattern,{P}x{Q}x{kh}x{kw},s{stride},conn{connectivity:.1f}",
            us_tap,
            f"unreordered_us={us_plain:.1f};"
            f"reorder_speedup={us_plain / us_tap:.2f}x;"
            f"flops_saved_exec={tap.flops_saved:.2f};"
            f"raw_zero_frac={1 - tap.density:.2f};"
            f"L={plain.L_max}->{tap.L_effective:.2f};"
            f"alive_band={tap.n_alive}/{tap.shape[0]};"
            f"dense_us={us_dense:.1f};max_err={err:.1e}")


def bench(fast=True):
    rows = []
    # Fig 5 analogue: kernel-block sweep on a serving-ish 3x3 conv
    for kb in (((4, 4), (8, 8)) if fast else ((4, 4), (8, 8), (16, 16))):
        rows.append(_layer_row(128, 64, 3, 3, 1, kb))
    # the paper's non-3x3 point: 5x5 kernel, stride 2
    rows.append(_layer_row(128, 64, 5, 5, 2, (8, 8)))
    # tap-gather rows: pure 4-of-9 patterns, patterns + connectivity, and
    # the connectivity-only 5x5 — executed-tap savings, not raw density
    rows.append(_pattern_row(128, 64, 3, 3, 1, 0.0))
    rows.append(_pattern_row(128, 64, 3, 3, 1, 0.5))
    rows.append(_pattern_row(128, 64, 5, 5, 2, 0.5))
    return rows
