"""Paper Table 4: rule-based vs search-based mapping vs PatDNN
(pattern-only) — compression + modeled latency on a conv net AND on the
assigned LM archs (the generalization the paper argues for)."""
import jax
import numpy as np

from benchmarks.common import train_convnet, eval_convnet
from repro import configs
from repro.core import mapper_rule as MR
from repro.core import mapper_search as MS
from repro.core import reweighted as RW
from repro.core import regularity as R
from repro.core.latency_model import matmul_latency
from repro.models import convnet as C


def _convnet_eval_factory(dense, steps):
    """evaluate_fn(spec) for the search: one-shot prune + short retrain."""
    names = [a[0] for a in C.MOBILE_TINY]

    def evaluate(spec):
        masks = {}
        for (name, out, kh, kw, stride, dw) in C.MOBILE_TINY:
            choice = RW.match(spec, name)
            if choice is None or choice.scheme == "none" or dw:
                continue
            w = dense[name]["w"]
            try:
                if choice.scheme == "pattern":
                    if (kh, kw) != (3, 3):
                        continue
                    masks[name] = R.pattern_mask(w, 0.5)
                elif choice.scheme == "block_punched" and w.ndim == 4:
                    b = (min(choice.block[0], w.shape[0]),
                         min(choice.block[1], w.shape[1]))
                    masks[name] = R.block_punched_mask(w, b, rate=0.8)
                else:
                    masks[name] = R.make_mask(w, choice.scheme,
                                              choice.block, rate=0.8)
            except AssertionError:
                continue
        p = train_convnet(arch=C.MOBILE_TINY, steps=steps, params=dense,
                          masks=masks)
        return eval_convnet(p, arch=C.MOBILE_TINY, masks=masks)
    return evaluate


def bench(fast=True):
    steps = 25 if fast else 80
    rows = []
    layers = MR.conv_layers([
        (n, 16 // max(s, 1), cin, o, kh, kw, dw) for
        (n, o, kh, kw, s, dw), cin in zip(
            C.MOBILE_TINY, [3, 32, 32, 64, 64, 128])])

    dense = train_convnet(arch=C.MOBILE_TINY, steps=3 * steps, seed=3)
    evaluate = _convnet_eval_factory(dense, steps)

    # PatDNN-style: pattern on 3x3 only, nothing else prunable
    pat_spec = [(l.path, RW.SchemeChoice(
        "pattern" if l.kind == "conv3x3" else "none")) for l in layers]
    acc = evaluate(pat_spec)
    rows.append(("table4,patdnn_pattern_only", 0.0, f"acc={acc:.3f}"))

    # rule-based (training-free mapping)
    spec_r, rep = MR.map_rules(layers, dataset_hard=False, compression=5.0)
    acc = evaluate(spec_r)
    rows.append(("table4,rule_based", MR.total_latency(rep) * 1e6,
                 f"acc={acc:.3f}"))

    # search-based (REINFORCE, small budget)
    best, hist = MS.search(layers, evaluate, iters=6 if fast else 20,
                           samples=3, latency_weight=2e2,
                           key=jax.random.PRNGKey(0))
    acc = evaluate(best)
    rows.append(("table4,search_based", 0.0,
                 f"acc={acc:.3f};reward_gain="
                 f"{np.mean(hist[-2:]) - np.mean(hist[:2]):.4f}"))

    # LM archs: rule-based mapping latency vs pattern-inapplicable baseline
    for arch in ("yi-9b", "mixtral-8x7b", "mamba2-1.3b"):
        cfg = configs.get(arch)
        lm = MR.lm_layers(cfg, tokens=32768)
        spec, rep = MR.map_rules(lm, dataset_hard=True, compression=8.0)
        t_mapped = MR.total_latency(rep)
        t_dense = sum(matmul_latency(l.M, l.K, l.N) * l.count
                      for l in lm if l.kind == "fc")
        rows.append((f"table4,lm,{arch}", t_mapped * 1e6,
                     f"dense_us={t_dense*1e6:.0f};"
                     f"speedup={t_dense/max(t_mapped,1e-12):.2f}x"))
    return rows
