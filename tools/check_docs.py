"""Docs link checker (CI docs job): every relative markdown link in
README.md and docs/ must point at a file or directory that exists in the
repo.

Network-free on purpose — external http(s) links are counted but not
fetched (CI runners and dev sandboxes should not flake on the internet);
what this catches is the common rot: a renamed module, a moved doc, a
deleted example still referenced from the README.

Exit status: 0 when every relative link resolves, 1 otherwise (each
broken link is printed as ``file: target``).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# [text](target) — excluding images' leading ! does not matter for existence
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    """README.md plus every markdown file under docs/."""
    out = [ROOT / "README.md"]
    out += sorted((ROOT / "docs").glob("**/*.md"))
    return [p for p in out if p.exists()]


def check_file(path: pathlib.Path):
    """Returns (broken, n_relative, n_external) for one markdown file."""
    broken, n_rel, n_ext = [], 0, 0
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            n_ext += 1
            continue
        if target.startswith("#"):          # intra-page anchor
            continue
        n_rel += 1
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).exists():
            broken.append(f"{path.relative_to(ROOT)}: {target}")
    return broken, n_rel, n_ext


def main() -> int:
    """Check every doc file; print a summary and broken links."""
    files = doc_files()
    if not files:
        print("no markdown files found to check", file=sys.stderr)
        return 1
    broken, n_rel, n_ext = [], 0, 0
    for path in files:
        b, r, e = check_file(path)
        broken += b
        n_rel += r
        n_ext += e
    for line in broken:
        print(f"BROKEN: {line}", file=sys.stderr)
    if broken:
        return 1
    print(f"docs link check passed: {n_rel} relative link(s) across "
          f"{len(files)} file(s) resolve ({n_ext} external link(s) not "
          "fetched)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
